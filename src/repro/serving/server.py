"""GraphServer — multi-request LLM serving on the MediaPipe graph runtime.

The server owns a continuous-batching graph
(:func:`repro.serving.pipeline.build_continuous_serving_graph`): concurrent
``submit`` calls feed request packets into the graph input stream, a
``FlowLimiterCalculator`` admits them under ``max_in_flight``, the
``ContinuousBatchCalculator`` inserts them into a running slot-based decode
batch, and generated tokens come back through an ``OutputStreamPoller`` on
the ``tokens`` stream that a background dispatcher thread routes to
:class:`RequestHandle`s (the ``responses`` stream feeds the limiter's
FINISHED loopback).

    engine = LLMEngine(cfg, max_len=128)
    with GraphServer(engine, num_slots=4) as server:
        h = server.submit([1, 2, 3], max_new_tokens=8)
        for tok in h.stream():       # tokens as they are generated
            ...
        tokens = h.result()          # the full generation, np.int32 [n]

Determinism: greedy decode through the server is bit-identical to
``LLMEngine.generate`` one request at a time — prefill batches group only
equal-length prompts (no padding) and every decode-batch row op is
row-independent.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np

from ..core.graph import Graph, OutputStreamPoller
from ..core.metrics import MetricsRegistry
from .batching import DeadlineExceeded
from .engine import LLMEngine
from .kvcache.backend import max_request_tokens
from .observe import FlightRecorder, export_run
from .pipeline import build_continuous_serving_graph


class RequestHandle:
    """Client-side handle to one in-flight generation request.

    A request can end without a final token: cancellation
    (:meth:`cancel` / server-side disconnect) or a missed deadline.
    :meth:`stream` then simply ends and :meth:`result` returns the
    tokens generated so far — check :attr:`finish_reason`
    (``"cancelled"`` / ``"deadline"`` vs ``"eos"`` / ``"length"``)."""

    _END = object()

    def __init__(self, request_id: Any, server: "GraphServer" = None):
        self.id = request_id
        self._server = server
        self._events: "queue.Queue" = queue.Queue()
        self._done = threading.Event()
        self._mutex = threading.Lock()
        self._tokens: List[int] = []
        self._listeners: List[Callable[[Optional[int], bool, str],
                                       None]] = []
        self._result: Optional[np.ndarray] = None
        self._finish_reason = ""
        self._error: Optional[BaseException] = None
        #: scheduler-side per-request metrics record (TTFT, queue wait,
        #: accepted/drafted, preemptions ...), set with the final token —
        #: see docs/OBSERVABILITY.md
        self.metrics: Optional[Dict[str, Any]] = None

    # -- fed by the server's dispatcher thread (one thread: the TOKEN
    # stream is the single source of truth, so tokens and completion can
    # never be observed out of order) ----------------------------------
    def _on_token(self, token: Optional[int], finished: bool,
                  reason: str, metrics: Optional[Dict[str, Any]] = None
                  ) -> None:
        with self._mutex:
            if token is not None:
                self._tokens.append(token)
                self._events.put(token)
            if finished:
                self._result = np.asarray(self._tokens, np.int32)
                self._finish_reason = reason
                if metrics is not None:
                    self.metrics = metrics
                self._events.put(self._END)
                self._done.set()
            for fn in self._listeners:
                fn(token, finished, reason)

    def _on_error(self, err: BaseException) -> None:
        with self._mutex:
            if self._done.is_set():
                return
            self._error = err
            self._events.put(self._END)
            self._done.set()
            for fn in self._listeners:
                fn(None, True, "error")

    def add_listener(self, fn: Callable[[Optional[int], bool, str],
                                        None]) -> None:
        """Register ``fn(token, finished, reason)`` to be called for
        every event on this request (from the server's dispatcher
        thread — keep it non-blocking, e.g. ``call_soon_threadsafe``).
        Events that arrived before registration are replayed first, so a
        listener attached after :meth:`GraphServer.submit` returns never
        misses a token; a replayed completion arrives as a token-less
        ``(None, True, reason)`` event."""
        with self._mutex:
            for t in self._tokens:
                fn(t, False, "")
            if self._done.is_set():
                fn(None, True,
                   "error" if self._error is not None
                   else self._finish_reason)
                return
            self._listeners.append(fn)

    # -- client API ----------------------------------------------------
    def stream(self, timeout: Optional[float] = 120.0) -> Iterator[int]:
        """Yield generated token ids as they arrive, until completion."""
        while True:
            ev = self._events.get(timeout=timeout)
            if ev is self._END:
                if self._error is not None:
                    raise RuntimeError(
                        f"request {self.id!r} failed") from self._error
                return
            yield ev

    def result(self, timeout: Optional[float] = 120.0) -> np.ndarray:
        """Block until finished; returns the generated tokens [n] int32."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.id!r} not finished "
                               f"after {timeout}s")
        if self._error is not None:
            raise RuntimeError(f"request {self.id!r} failed") from self._error
        return self._result

    @property
    def finish_reason(self) -> str:
        return self._finish_reason

    def done(self) -> bool:
        return self._done.is_set()

    def cancel(self) -> bool:
        """Ask the server to cancel this request (idempotent; safe after
        completion — the post-EOS race is a no-op).  Returns True if the
        request was still pending when the cancel was sent."""
        if self._server is None or self._done.is_set():
            return False
        return self._server.cancel(self.id)


class GraphServer:
    """Continuous-batching LLM server over the graph runtime.

    Thread-safe: ``submit`` may be called from any number of client
    threads.

    Overload behaviour: with ``drop_on_overload=True`` the limiter keeps
    **no** waiting queue (``queue_size`` is ignored) and sheds every
    request beyond ``max_in_flight`` upstream of prefill, mirroring the
    paper's real-time pipelines where stale frames are simply discarded.
    With the default ``drop_on_overload=False`` requests wait in the
    limiter's queue, but a burst beyond ``max_in_flight + queue_size``
    outstanding is still shed.  Either way a shed request's handle stays
    unresolved until :meth:`close` fails it (poll :meth:`stats` for the
    drop count).
    """

    def __init__(self, engine: LLMEngine, *, num_slots: int = 4,
                 max_in_flight: int = 0, queue_size: int = 1024,
                 max_new_tokens: int = 16, eos_id: Optional[int] = None,
                 drop_on_overload: bool = False, enable_tracer: bool = True,
                 chunk_size: Optional[int] = None,
                 speculate_k: int = 0, spec_ngram: int = 3,
                 paged: bool = False, num_blocks: int = 0,
                 block_size: int = 16, prefix_sharing: bool = True,
                 admission: str = "preempt", watermark: int = 0,
                 backend: Optional[str] = None, spec_window: int = 8,
                 observe_dir: Optional[str] = None,
                 flight_max_dumps: int = 8):
        self.engine = engine
        self.observe_dir = observe_dir
        self._default_max_new = max_new_tokens
        # "backend" names the layout outright ("slot" | "paged" | "state"
        # | "hybrid") and wins over the legacy paged flag; "state" serves
        # recurrent/mixed stacks from O(1) state slabs, "hybrid" pages
        # attention K/V alongside them (docs/STATE_CACHE.md)
        kind = backend if backend is not None else \
            ("paged" if paged else "slot")
        self._backend_kind = kind
        if speculate_k:
            # fail in the caller's thread, not inside the graph run
            engine.check_spec_support(kind)
        self._paged = kind in ("paged", "hybrid")   # block-math capacity
        self._block_size = block_size
        if self._paged:
            if num_blocks <= 0:
                # arena sized to num_slots worst-case rows by default —
                # the same memory the slot cache would have used.  Under
                # a serving mesh the arena's K/V leaves are sharded
                # across TP ranks, so at fixed PER-RANK memory the pool
                # holds cache_shards() times as many blocks: capacity
                # scales with the mesh (docs/SHARDING.md)
                num_blocks = 1 + engine.cache_shards() * num_slots * \
                    (engine.max_len // block_size)
            if max_in_flight <= 0:
                # The limiter bounds scheduling burst; REAL memory
                # admission is the paged backend's block-availability
                # check.  A request that cannot take its blocks waits
                # inside the engine subsystem holding its limiter budget,
                # so sustained block pressure backs up into the limiter
                # and on to submitters.  The default is therefore at
                # least as permissive as slot mode, plus however many
                # worst-case rows the arena actually holds (a big arena
                # should admit more than 2*num_slots).
                max_in_flight = max(
                    2 * num_slots,
                    (num_blocks - 1) // (engine.max_len // block_size))
        self._num_blocks = num_blocks
        cfg = build_continuous_serving_graph(
            num_slots=num_slots, max_in_flight=max_in_flight,
            queue_size=queue_size, max_new_tokens=max_new_tokens,
            eos_id=eos_id, drop_on_overload=drop_on_overload,
            enable_tracer=enable_tracer, chunk_size=chunk_size,
            speculate_k=speculate_k, spec_ngram=spec_ngram,
            paged=paged, num_blocks=num_blocks, block_size=block_size,
            prefix_sharing=prefix_sharing, admission=admission,
            watermark=watermark, backend=backend,
            spec_window=spec_window)
        self.graph = Graph(cfg, side_packets={"engine": engine})
        self._token_poller = self.graph.add_output_stream_poller("tokens")
        self._handles: Dict[Any, RequestHandle] = {}
        self._lock = threading.Lock()
        self._ts = itertools.count()
        self._ctrl_ts = itertools.count()
        self._auto_id = itertools.count()
        self._closed = False
        self._final_stats: Dict[str, Any] = {}
        self.graph.start_run()
        # start_run opens calculators on executor threads; block until
        # the engine node's open() (scheduler + device cache
        # construction) lands so stats() deterministically reports the
        # scheduler counters from the moment the constructor returns —
        # and so a backend/arch mismatch raises here, not on first use
        engine_node = next(n for n in self.graph.nodes
                           if n.name == "engine")
        deadline = time.monotonic() + 300.0
        while not hasattr(engine_node.calculator, "sched"):
            self.graph._check_error()
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "engine calculator did not finish opening")
            time.sleep(0.001)
        self._engine_calc = engine_node.calculator
        # flight recorder (docs/OBSERVABILITY.md): incidents dump the
        # last-N trace events + metrics + scheduler state to observe_dir
        self._recorder: Optional[FlightRecorder] = None
        if observe_dir is not None:
            obs = getattr(self._engine_calc, "observer", None)
            rec = FlightRecorder(
                observe_dir, max_dumps=flight_max_dumps,
                registry=obs.registry if obs is not None else None,
                mesh=engine.mesh_desc)
            rec.bind(events_fn=self.graph.tracer.events,
                     metrics_fn=self.metrics,
                     state_fn=self._engine_calc.sched.debug_state)
            if obs is not None and obs.enabled:
                # NULL_OBSERVER is a shared singleton: never mutate it
                obs.recorder = rec
            self._recorder = rec
        self._threads = [
            threading.Thread(target=self._pump_tokens, daemon=True,
                             name="graphserver-tokens"),
        ]
        for t in self._threads:
            t.start()

    # -- client API ----------------------------------------------------
    def submit(self, tokens, max_new_tokens: Optional[int] = None,
               eos_id: Optional[int] = None, priority: int = 0,
               speculate_k: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               ttft_ms: Optional[float] = None,
               request_id: Any = None) -> RequestHandle:
        """Enqueue one generation request; returns immediately.

        ``priority``: higher values are admitted first and preempted
        last (paged backend under block pressure).

        ``speculate_k``: per-request speculative draft budget (overrides
        the server default; 0 disables speculation for this request —
        see docs/SPECULATIVE.md).

        ``deadline_ms`` / ``ttft_ms``: SLO budgets relative to this call
        — the whole request / the first token must land within that many
        milliseconds or the request is terminated with
        ``finish_reason="deadline"`` (tokens streamed so far stay
        valid).  A TTFT target also lets the request preempt a
        strictly-lower-priority active one when no slot is free
        (docs/FRONTEND.md).  A non-positive budget raises
        :class:`DeadlineExceeded` here, client-side; the graph payload
        carries the *absolute* times, so a budget that expires while the
        request sits in the admission queue becomes a ``deadline``
        completion, never a graph error.

        Invalid requests are rejected here, client-side — an error thrown
        inside a graph node would terminate the whole run.  The check
        mirrors ``Scheduler.submit``: the cap is the backend's REAL
        capacity (paged: arena blocks, not just engine max_len)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        slo: Dict[str, float] = {}
        now = None
        for key, rel in (("deadline", deadline_ms),
                         ("ttft_deadline", ttft_ms)):
            if rel is None:
                continue
            rel = float(rel)
            if rel <= 0:
                raise DeadlineExceeded(
                    f"request {request_id!r}: "
                    f"{'deadline_ms' if key == 'deadline' else 'ttft_ms'}"
                    f"={rel:g} is already expired at submit")
            now = time.monotonic() if now is None else now
            slo[key] = now + rel / 1e3
        if speculate_k is not None:
            if int(speculate_k) < 0:
                raise ValueError(f"speculate_k must be >= 0, "
                                 f"got {int(speculate_k)}")
            if int(speculate_k) > 0:
                self.engine.check_spec_support(self._backend_kind)
        new = self._default_max_new if max_new_tokens is None \
            else int(max_new_tokens)
        if tokens.size == 0:
            raise ValueError("empty prompt")
        # state slabs are O(1) per request, so the state backend's only
        # bound is engine max_len (num_blocks=0 skips the block math);
        # hybrid keeps the block math for its attention layers
        cap = max_request_tokens(
            self.engine.max_len,
            self._num_blocks if self._paged else 0, self._block_size)
        if tokens.size + new > cap:
            detail = f"engine max_len ({self.engine.max_len})" \
                if not self._paged else \
                (f"backend capacity ({cap} tokens: "
                 f"{self._num_blocks - 1} usable blocks x "
                 f"{self._block_size}, engine max_len "
                 f"{self.engine.max_len})")
            raise ValueError(
                f"prompt ({tokens.size}) + max_new_tokens ({new}) "
                f"exceeds {detail}")
        with self._lock:
            if self._closed:
                raise RuntimeError("server is closed")
            if request_id is None:
                request_id = f"req-{next(self._auto_id)}"
            if request_id in self._handles:
                raise ValueError(f"duplicate request id {request_id!r}")
            handle = RequestHandle(request_id, self)
            self._handles[request_id] = handle
            payload = {"tokens": tokens, "id": request_id}
            payload.update(slo)
            if max_new_tokens is not None:
                payload["max_new_tokens"] = int(max_new_tokens)
            if eos_id is not None:
                payload["eos_id"] = int(eos_id)
            if priority:
                payload["priority"] = int(priority)
            if speculate_k is not None:
                payload["speculate_k"] = int(speculate_k)
            # feed the graph under the server lock: stream timestamps must
            # be added in allocation order or a faster thread would trip
            # the monotonicity check.  (The requests edge is unbounded, so
            # this never blocks on back-pressure.)
            self.graph.add_packet_to_input_stream("requests", payload,
                                                  next(self._ts))
        return handle

    def generate(self, tokens, max_new_tokens: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 timeout: Optional[float] = 120.0) -> np.ndarray:
        """Blocking convenience wrapper: submit + result."""
        return self.submit(tokens, max_new_tokens, eos_id).result(timeout)

    def cancel(self, request_id: Any) -> bool:
        """Cancel a request at any lifecycle point (queued in the
        limiter, waiting for a slot, mid-prefill-chunk, mid-decode,
        between speculative verify ticks).  The cancel travels on the
        graph's ``control`` input stream, which bypasses the flow
        limiter — it gets through even (especially) when the admission
        queue is full.  The request's handle completes with
        ``finish_reason="cancelled"`` and whatever tokens were already
        streamed; all of its cache memory (slot row / blocks / trie
        refs) is released.  Idempotent; cancelling an id that already
        finished (the post-EOS race) is a no-op.  Returns True if the
        request was still pending when the cancel was sent."""
        with self._lock:
            if self._closed:
                return False
            pending = request_id in self._handles
            # under the lock for the same timestamp-monotonicity reason
            # as submit (the control edge is unbounded: never blocks)
            self.graph.add_packet_to_input_stream(
                "control", {"op": "cancel", "id": request_id},
                next(self._ctrl_ts))
        return pending

    def stats(self) -> Dict[str, Any]:
        """Limiter + scheduler counters (live)."""
        out: Dict[str, Any] = {}
        for node in self.graph.nodes:
            if node.name == "limiter":
                limiter = node.calculator
                out["admitted"] = getattr(limiter, "admitted", 0)
                out["dropped"] = getattr(limiter, "dropped", 0)
                out["in_flight"] = getattr(limiter, "in_flight", 0)
            elif node.name == "engine":
                sched = getattr(node.calculator, "sched", None)
                if sched is not None:
                    out["scheduler"] = dict(sched.stats)
                    pool = getattr(sched, "pool", None)
                    if pool is not None:
                        out["block_pool"] = dict(
                            pool.stats, num_blocks=pool.num_blocks,
                            block_size=pool.block_size,
                            in_use=pool.blocks_in_use,
                            free=pool.free_blocks,
                            reserved=pool.reserved_blocks)
        return out

    def metrics_registry(self) -> MetricsRegistry:
        """Merged view of the engine's profiling registry and the
        scheduler observer's lifecycle registry (both log-bucketed, so
        the merge is lossless — docs/OBSERVABILITY.md)."""
        regs = [self.engine.metrics]
        obs = getattr(self._engine_calc, "observer", None)
        if obs is not None:
            regs.append(obs.registry)
        return MetricsRegistry.merged(regs)

    def metrics(self) -> Dict[str, Any]:
        """JSON-serialisable snapshot of every counter/gauge/histogram
        (TTFT, ITL, queue wait, batch occupancy, jit compiles ...)."""
        return self.metrics_registry().snapshot()

    def metrics_text(self) -> str:
        """The same snapshot in Prometheus text exposition format."""
        return self.metrics_registry().to_prometheus()

    def dump_observability(self, out_dir: Optional[str] = None
                           ) -> Dict[str, str]:
        """Export the run's full observability artifact set (chrome
        trace, per-request Perfetto tracks, JSON timelines, metrics
        snapshot + Prometheus text, provenance) to ``out_dir`` (defaults
        to the server's ``observe_dir``).  Callable live or after
        :meth:`close`.  Returns {artifact name: path}."""
        out_dir = out_dir if out_dir is not None else self.observe_dir
        if out_dir is None:
            raise ValueError("no output directory: pass out_dir or "
                             "construct the server with observe_dir=")
        return export_run(out_dir, tracer=self.graph.tracer,
                          node_names=self.graph.node_names(),
                          registry=self.metrics_registry())

    def close(self, timeout: float = 300.0) -> Dict[str, Any]:
        """Stop accepting requests, drain in-flight work, stop the graph.
        Returns the final :meth:`stats` snapshot."""
        with self._lock:
            if self._closed:
                return self._final_stats
            self._closed = True
        self.graph.close_all_input_streams()
        try:
            self.graph.wait_until_done(timeout=timeout)
        finally:
            for t in self._threads:
                t.join(timeout=10.0)
            self._fail_pending(RuntimeError("server closed"))
        self._final_stats = self.stats()
        return self._final_stats

    def __enter__(self) -> "GraphServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- dispatchers ----------------------------------------------------
    def _handle_of(self, rid: Any) -> Optional[RequestHandle]:
        with self._lock:
            return self._handles.get(rid)

    def _pump_tokens(self) -> None:
        self._pump(self._token_poller, self._dispatch_token)

    def _pump(self, poller: OutputStreamPoller, dispatch) -> None:
        try:
            while True:
                pkt = poller.next(timeout=None)
                if pkt is None:          # stream closed and drained
                    return
                dispatch(pkt.payload)
        except BaseException as e:       # graph error: fail fast
            if self._recorder is not None:
                self._recorder.incident("executor_error",
                                        f"{type(e).__name__}: {e}")
            self._fail_pending(e)

    def _dispatch_token(self, payload: Dict[str, Any]) -> None:
        h = self._handle_of(payload["id"])
        if h is not None:
            h._on_token(payload["token"], payload["finished"],
                        payload.get("finish_reason", ""),
                        payload.get("metrics"))
            if payload["finished"]:
                # prune: the handle owns its result now; keeping it in the
                # server map would grow memory forever on a long-lived
                # server and block the id from ever being reused
                with self._lock:
                    self._handles.pop(payload["id"], None)

    def _fail_pending(self, err: BaseException) -> None:
        with self._lock:
            handles = list(self._handles.values())
        for h in handles:
            h._on_error(err)
