"""LLM serving on the graph runtime — the public serving API.

The stack, bottom-up (``pydoc`` each module for reference docs):

* :class:`LLMEngine` (``engine.py``) — jitted prefill / decode /
  extend / verify over a model from the zoo, dispatched on a cache
  backend's layout.
* :class:`CacheBackend` / :class:`SlotBackend` / :class:`PagedBackend`
  / :class:`StateBackend` / :class:`HybridBackend` (``kvcache/``) — the
  memory layer: contiguous slot rows, a paged block-pool arena with
  ref-counted prefix sharing, O(1) recurrent state slabs, or the
  Jamba-style per-layer mix (docs/KV_CACHE.md, docs/STATE_CACHE.md).
* :class:`Scheduler` (``batching.py``) — continuous batching policy:
  priority admission, chunked prefill, preemption, self-speculative
  decoding (docs/SCHEDULER.md, docs/SPECULATIVE.md).
* :class:`GraphServer` (``server.py``) — the whole thing wired as a
  MediaPipe-style graph with flow-limited admission and streamed
  responses (docs/ARCHITECTURE.md §5).
* :class:`AsyncFrontend` (``frontend.py``) — the asyncio front door:
  per-token async streaming, client disconnect → cancellation,
  deadlines/TTFT targets, retry/timeout policy (docs/FRONTEND.md).

Quickstart::

    from repro.configs import get_config
    from repro.serving import GraphServer, LLMEngine

    engine = LLMEngine(get_config("minicpm_2b").reduced(), max_len=128)
    with GraphServer(engine, num_slots=4, speculate_k=4) as server:
        tokens = server.submit([1, 2, 3, 4]).result()
"""
from .engine import LLMEngine
from .batching import DeadlineExceeded, Request, Scheduler, TokenEvent
from .calculators import (BatcherCalculator, ContinuousBatchCalculator,
                          UnbatchCalculator, LLMPrefillCalculator,
                          LLMDecodeLoopCalculator)
from .frontend import AsyncFrontend, Policy, RequestTimeout
from .kvcache import (BlockPool, BlockPoolError, CacheBackend,
                      CachePressure, HybridBackend, PagedBackend,
                      PrefixIndex, SlotBackend, StateBackend,
                      make_backend)
from .observe import (FlightRecorder, NULL_OBSERVER, Observer,
                      RequestTimeline, export_run)
from .pipeline import build_continuous_serving_graph, build_serving_graph
from .server import GraphServer, RequestHandle
from .speculative import lookup_draft

__all__ = ["LLMEngine", "BatcherCalculator", "ContinuousBatchCalculator",
           "UnbatchCalculator", "LLMPrefillCalculator",
           "LLMDecodeLoopCalculator", "Request", "Scheduler", "TokenEvent",
           "DeadlineExceeded", "AsyncFrontend", "Policy", "RequestTimeout",
           "BlockPool", "BlockPoolError", "CacheBackend", "CachePressure",
           "HybridBackend", "PagedBackend", "PrefixIndex", "SlotBackend",
           "StateBackend", "make_backend",
           "build_serving_graph", "build_continuous_serving_graph",
           "GraphServer", "RequestHandle", "lookup_draft",
           "FlightRecorder", "NULL_OBSERVER", "Observer",
           "RequestTimeline", "export_run"]
