from .engine import LLMEngine
from .calculators import (BatcherCalculator, UnbatchCalculator,
                          LLMPrefillCalculator, LLMDecodeLoopCalculator)
from .pipeline import build_serving_graph

__all__ = ["LLMEngine", "BatcherCalculator", "UnbatchCalculator",
           "LLMPrefillCalculator", "LLMDecodeLoopCalculator",
           "build_serving_graph"]
