from .engine import LLMEngine
from .batching import Request, Scheduler, TokenEvent
from .calculators import (BatcherCalculator, ContinuousBatchCalculator,
                          UnbatchCalculator, LLMPrefillCalculator,
                          LLMDecodeLoopCalculator)
from .kvcache import (BlockPool, BlockPoolError, CacheBackend,
                      CachePressure, PagedBackend, PrefixIndex,
                      SlotBackend, make_backend)
from .pipeline import build_continuous_serving_graph, build_serving_graph
from .server import GraphServer, RequestHandle

__all__ = ["LLMEngine", "BatcherCalculator", "ContinuousBatchCalculator",
           "UnbatchCalculator", "LLMPrefillCalculator",
           "LLMDecodeLoopCalculator", "Request", "Scheduler", "TokenEvent",
           "BlockPool", "BlockPoolError", "CacheBackend", "CachePressure",
           "PagedBackend", "PrefixIndex", "SlotBackend", "make_backend",
           "build_serving_graph", "build_continuous_serving_graph",
           "GraphServer", "RequestHandle"]
