from .engine import LLMEngine
from .batching import PagedScheduler, Request, SlotScheduler, TokenEvent
from .calculators import (BatcherCalculator, ContinuousBatchCalculator,
                          UnbatchCalculator, LLMPrefillCalculator,
                          LLMDecodeLoopCalculator)
from .kvcache import BlockPool, BlockPoolError, PrefixIndex
from .pipeline import build_continuous_serving_graph, build_serving_graph
from .server import GraphServer, RequestHandle

__all__ = ["LLMEngine", "BatcherCalculator", "ContinuousBatchCalculator",
           "UnbatchCalculator", "LLMPrefillCalculator",
           "LLMDecodeLoopCalculator", "Request", "SlotScheduler",
           "PagedScheduler", "TokenEvent", "BlockPool", "BlockPoolError",
           "PrefixIndex", "build_serving_graph",
           "build_continuous_serving_graph", "GraphServer", "RequestHandle"]
