"""Continuous batching: slot-based KV-cache management + request scheduler.

This is the core of the GraphServer subsystem (vLLM-style continuous
batching mapped onto the repo's MediaPipe-like graph runtime).  The decode
batch is a fixed set of ``num_slots`` *slots*; each slot holds one
in-flight request's KV/recurrent cache row.  New requests are prefilled
(grouped by equal prompt length so one jitted prefill serves the group)
and **inserted** into free slots while other slots keep decoding; finished
requests are **evicted** so their slot is immediately reusable.  Per-slot
positions feed the model's vectorised ``cache_pos`` decode path
(:func:`repro.runtime.steps.make_slot_decode_step`), which keeps batched
greedy decode bit-identical to one-request-at-a-time decode — every row op
is row-independent.

The scheduler here is host-side and graph-agnostic: the MediaPipe wiring
(admission through ``FlowLimiterCalculator``, the tick loopback that lets
the graph scheduler interleave admission with decode steps) lives in
:mod:`repro.serving.calculators` / :mod:`repro.serving.pipeline`.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from jax import lax
from jax.tree_util import tree_map_with_path


def slot_batch_axis(path) -> int:
    """Axis of the slot (batch) dimension in a cache leaf.

    ``prefill`` returns head-layer leaves shaped [B, ...] and scanned-block
    leaves shaped [R, B, ...] (R = layer-group repeat count), so the batch
    axis is 1 under the top-level ``"blocks"`` key and 0 everywhere else.
    """
    return 1 if (path and getattr(path[0], "key", None) == "blocks") else 0


def make_slot_insert():
    """Build ``insert(cache, rows, row, slot)``: copy cache row ``row`` of a
    freshly prefilled batch into slot ``slot`` of the persistent slot cache.
    ``row``/``slot`` are traced scalars, so one compilation covers every
    slot index (recompiles only on a new prefill batch width)."""

    def insert(cache, rows, row, slot):
        def ins(path, big, rs):
            ax = slot_batch_axis(path)
            r = lax.dynamic_slice_in_dim(rs, row, 1, axis=ax)
            return lax.dynamic_update_slice_in_dim(
                big, r.astype(big.dtype), slot, axis=ax)

        return tree_map_with_path(ins, cache, rows)

    return insert


@dataclasses.dataclass
class Request:
    """One generation request as tracked by the scheduler."""
    id: Any
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    finished: bool = False
    finish_reason: str = ""            # "eos" | "length"


@dataclasses.dataclass
class TokenEvent:
    """One generated token (or the request's completion)."""
    request: Request
    token: int
    index: int                          # 0-based position in the generation
    finished: bool


class SlotScheduler:
    """Admission + per-step decode over a fixed-width slot batch.

    Drive it with::

        sched.submit(payload)      # any number of times, any time
        events = sched.admit()     # prefill waiting requests into free slots
        events += sched.step()     # one decode step across active slots

    until :meth:`has_work` is False.  ``admit``/``step`` return
    :class:`TokenEvent` lists in deterministic (slot) order.
    """

    def __init__(self, engine, num_slots: int = 4, *,
                 max_new_tokens: int = 16, eos_id: Optional[int] = None,
                 pad_id: int = 0):
        if engine.cfg.is_encoder_decoder:
            raise ValueError("continuous batching supports decoder-only "
                             "models (encoder-decoder prefill needs "
                             "enc_embeds plumbing)")
        self.engine = engine
        self.num_slots = int(num_slots)
        self.default_max_new = int(max_new_tokens)
        self.default_eos = eos_id
        self.pad_id = int(pad_id)
        self.waiting: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self.free: List[int] = list(range(self.num_slots))  # LIFO reuse
        self.cache = engine.new_slot_cache(self.num_slots)
        self.positions = np.zeros(self.num_slots, np.int32)
        self.last_tokens = np.full(self.num_slots, self.pad_id, np.int32)
        self.stats: Dict[str, Any] = {
            "submitted": 0, "completed": 0, "decode_steps": 0,
            "prefill_calls": 0, "prefill_requests": 0,
            "prefill_padded_rows": 0,
            "evictions_eos": 0, "evictions_length": 0,
            "max_active_slots": 0,
            # peak requests inside the subsystem (waiting + active): with a
            # FlowLimiter upstream this must never exceed max_in_flight
            "max_outstanding": 0,
        }

    # -- state predicates -------------------------------------------------
    @property
    def active(self) -> int:
        return self.num_slots - len(self.free)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.active > 0

    # -- request intake ---------------------------------------------------
    def submit(self, payload: Dict[str, Any]) -> Request:
        """payload: {'tokens': [S] ints, 'id': any,
        'max_new_tokens': int?, 'eos_id': int?}"""
        prompt = np.asarray(payload["tokens"], np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + payload.get("max_new_tokens",
                                     self.default_max_new) > \
                self.engine.max_len:
            raise ValueError(
                f"request {payload.get('id')!r}: prompt ({prompt.size}) + "
                f"max_new_tokens exceeds engine max_len "
                f"({self.engine.max_len})")
        req = Request(
            id=payload.get("id"),
            prompt=prompt,
            max_new_tokens=int(payload.get("max_new_tokens",
                                           self.default_max_new)),
            eos_id=payload.get("eos_id", self.default_eos))
        self.waiting.append(req)
        self.stats["submitted"] += 1
        self.stats["max_outstanding"] = max(
            self.stats["max_outstanding"],
            self.stats["submitted"] - self.stats["completed"])
        return req

    # -- admission: dynamic prefill batching ------------------------------
    def admit(self) -> List[TokenEvent]:
        """Prefill waiting requests into free slots.

        Head-of-line requests with equal prompt length are prefilled as one
        batch (dynamic prefill batching); admission stays FIFO.  Prefill
        already yields each request's first generated token.

        The batch is padded to a power-of-two width with duplicates of its
        first row: group width depends on arrival timing, so without
        bucketing each new width is a fresh XLA compile at an unpredictable
        moment.  Padding rows are row-independent (they cannot perturb real
        rows) and are simply not inserted.
        """
        events: List[TokenEvent] = []
        while self.waiting and self.free:
            L = self.waiting[0].prompt.size
            group: List[Request] = []
            while (self.waiting and len(group) < len(self.free)
                   and self.waiting[0].prompt.size == L):
                group.append(self.waiting.popleft())
            width = 1
            while width < len(group):
                width *= 2
            prompts = np.stack([r.prompt for r in group]
                               + [group[0].prompt] * (width - len(group)))
            first, rows = self.engine.prefill(prompts)
            self.stats["prefill_calls"] += 1
            self.stats["prefill_requests"] += len(group)
            self.stats["prefill_padded_rows"] += width - len(group)
            for i, req in enumerate(group):
                slot = self.free.pop()
                req.slot = slot
                self.slots[slot] = req
                self.cache = self.engine.insert_slot(self.cache, rows,
                                                     i, slot)
                self.positions[slot] = req.prompt.size
                events.append(self._record(req, int(first[i])))
            self.stats["max_active_slots"] = max(
                self.stats["max_active_slots"], self.active)
        return events

    # -- one decode step over the slot mask -------------------------------
    def step(self) -> List[TokenEvent]:
        if self.active == 0:
            return []
        active = np.zeros(self.num_slots, bool)
        for slot, req in enumerate(self.slots):
            active[slot] = req is not None
        next_tok, self.cache = self.engine.decode_slots(
            self.cache, self.last_tokens, self.positions, active)
        self.stats["decode_steps"] += 1
        events = []
        for slot in np.nonzero(active)[0]:
            req = self.slots[slot]
            self.positions[slot] += 1
            events.append(self._record(req, int(next_tok[slot])))
        return events

    # -- bookkeeping ------------------------------------------------------
    def _record(self, req: Request, token: int) -> TokenEvent:
        req.tokens.append(token)
        self.last_tokens[req.slot] = token
        index = len(req.tokens) - 1
        if req.eos_id is not None and token == req.eos_id:
            req.finished, req.finish_reason = True, "eos"
            self.stats["evictions_eos"] += 1
        elif len(req.tokens) >= req.max_new_tokens:
            req.finished, req.finish_reason = True, "length"
            self.stats["evictions_length"] += 1
        if req.finished:
            self._evict(req)
        return TokenEvent(req, token, index, req.finished)

    def _evict(self, req: Request) -> None:
        """Free the request's slot.  The cache row is left as-is: a later
        insert overwrites the whole row, and inactive rows cannot perturb
        active ones (row-independent decode)."""
        slot = req.slot
        self.slots[slot] = None
        self.positions[slot] = 0
        self.last_tokens[slot] = self.pad_id
        self.free.append(slot)
        req.slot = -1
        self.stats["completed"] += 1
