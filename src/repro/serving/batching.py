"""Continuous batching: slot-based KV-cache management + request scheduler.

This is the core of the GraphServer subsystem (vLLM-style continuous
batching mapped onto the repo's MediaPipe-like graph runtime).  The decode
batch is a fixed set of ``num_slots`` *slots*; each slot holds one
in-flight request's KV/recurrent cache row.  New requests are prefilled
(grouped by equal prompt length so one jitted prefill serves the group)
and **inserted** into free slots while other slots keep decoding; finished
requests are **evicted** so their slot is immediately reusable.  Per-slot
positions feed the model's vectorised ``cache_pos`` decode path
(:func:`repro.runtime.steps.make_slot_decode_step`), which keeps batched
greedy decode bit-identical to one-request-at-a-time decode — every row op
is row-independent.

The scheduler here is host-side and graph-agnostic: the MediaPipe wiring
(admission through ``FlowLimiterCalculator``, the tick loopback that lets
the graph scheduler interleave admission with decode steps) lives in
:mod:`repro.serving.calculators` / :mod:`repro.serving.pipeline`.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from jax import lax
from jax.tree_util import tree_map_with_path


def slot_batch_axis(path) -> int:
    """Axis of the slot (batch) dimension in a cache leaf.

    ``prefill`` returns head-layer leaves shaped [B, ...] and scanned-block
    leaves shaped [R, B, ...] (R = layer-group repeat count), so the batch
    axis is 1 under the top-level ``"blocks"`` key and 0 everywhere else.
    """
    return 1 if (path and getattr(path[0], "key", None) == "blocks") else 0


def make_slot_insert():
    """Build ``insert(cache, rows, row, slot)``: copy cache row ``row`` of a
    freshly prefilled batch into slot ``slot`` of the persistent slot cache.
    ``row``/``slot`` are traced scalars, so one compilation covers every
    slot index (recompiles only on a new prefill batch width)."""

    def insert(cache, rows, row, slot):
        def ins(path, big, rs):
            ax = slot_batch_axis(path)
            r = lax.dynamic_slice_in_dim(rs, row, 1, axis=ax)
            return lax.dynamic_update_slice_in_dim(
                big, r.astype(big.dtype), slot, axis=ax)

        return tree_map_with_path(ins, cache, rows)

    return insert


def make_paged_insert(block_size: int):
    """Build ``insert(arena, rows, row, page_ids)``: scatter one prefilled
    cache row (shaped ``[B, S_cache, ...]``, ``S_cache`` a multiple of
    ``block_size``) into the paged arena, page by page.

    ``page_ids`` is a fixed-length [P] int32 vector — entry ``j`` is the
    arena block receiving the row's ``j``-th page, or 0 (the trash block)
    for pages that must not land anywhere: padding beyond the prompt, and
    pages whose content is already present as a shared prefix block
    (shared blocks are immutable — redirecting their writes to the trash
    block preserves that invariant).  Fixed length means one compilation
    covers every page count."""

    def insert(arena, rows, row, page_ids):
        def ins(path, big, rs):
            ax = slot_batch_axis(path)
            r = lax.dynamic_slice_in_dim(rs, row, 1, axis=ax)
            r = lax.squeeze(r, (ax,))
            if ax == 1:                     # scanned blocks: [R, S, ...]
                R_, S = r.shape[0], r.shape[1]
                pages = r.reshape((R_, S // block_size, block_size)
                                  + r.shape[2:])
                return big.at[:, page_ids].set(pages.astype(big.dtype))
            S = r.shape[0]                   # head layers: [S, ...]
            pages = r.reshape((S // block_size, block_size) + r.shape[1:])
            return big.at[page_ids].set(pages.astype(big.dtype))

        return tree_map_with_path(ins, arena, rows)

    return insert


@dataclasses.dataclass
class Request:
    """One generation request as tracked by the scheduler."""
    id: Any
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    finished: bool = False
    finish_reason: str = ""            # "eos" | "length"
    # paged-scheduler state (unused on the slot path)
    blocks: List[int] = dataclasses.field(default_factory=list)
    n_pages: int = 0                   # pages present in the block table
    reserved_left: int = 0             # reserved-but-unallocated pages
    prefix_len: int = 0                # tokens reused from shared blocks


@dataclasses.dataclass
class TokenEvent:
    """One generated token (or the request's completion)."""
    request: Request
    token: int
    index: int                          # 0-based position in the generation
    finished: bool


class SlotScheduler:
    """Admission + per-step decode over a fixed-width slot batch.

    Drive it with::

        sched.submit(payload)      # any number of times, any time
        events = sched.admit()     # prefill waiting requests into free slots
        events += sched.step()     # one decode step across active slots

    until :meth:`has_work` is False.  ``admit``/``step`` return
    :class:`TokenEvent` lists in deterministic (slot) order.
    """

    def __init__(self, engine, num_slots: int = 4, *,
                 max_new_tokens: int = 16, eos_id: Optional[int] = None,
                 pad_id: int = 0):
        if engine.cfg.is_encoder_decoder:
            raise ValueError("continuous batching supports decoder-only "
                             "models (encoder-decoder prefill needs "
                             "enc_embeds plumbing)")
        self.engine = engine
        self.num_slots = int(num_slots)
        self.default_max_new = int(max_new_tokens)
        self.default_eos = eos_id
        self.pad_id = int(pad_id)
        self.waiting: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self.free: List[int] = list(range(self.num_slots))  # LIFO reuse
        self.cache = self._make_cache()
        self.positions = np.zeros(self.num_slots, np.int32)
        self.last_tokens = np.full(self.num_slots, self.pad_id, np.int32)
        self.stats: Dict[str, Any] = {
            "submitted": 0, "completed": 0, "decode_steps": 0,
            "prefill_calls": 0, "prefill_requests": 0,
            "prefill_padded_rows": 0,
            "evictions_eos": 0, "evictions_length": 0,
            "max_active_slots": 0,
            # peak requests inside the subsystem (waiting + active): with a
            # FlowLimiter upstream this must never exceed max_in_flight
            "max_outstanding": 0,
        }

    def _make_cache(self):
        return self.engine.new_slot_cache(self.num_slots)

    # -- state predicates -------------------------------------------------
    @property
    def active(self) -> int:
        return self.num_slots - len(self.free)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.active > 0

    # -- request intake ---------------------------------------------------
    def submit(self, payload: Dict[str, Any]) -> Request:
        """payload: {'tokens': [S] ints, 'id': any,
        'max_new_tokens': int?, 'eos_id': int?}"""
        prompt = np.asarray(payload["tokens"], np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + payload.get("max_new_tokens",
                                     self.default_max_new) > \
                self.engine.max_len:
            raise ValueError(
                f"request {payload.get('id')!r}: prompt ({prompt.size}) + "
                f"max_new_tokens exceeds engine max_len "
                f"({self.engine.max_len})")
        req = Request(
            id=payload.get("id"),
            prompt=prompt,
            max_new_tokens=int(payload.get("max_new_tokens",
                                           self.default_max_new)),
            eos_id=payload.get("eos_id", self.default_eos))
        self.waiting.append(req)
        self.stats["submitted"] += 1
        self.stats["max_outstanding"] = max(
            self.stats["max_outstanding"],
            self.stats["submitted"] - self.stats["completed"])
        return req

    # -- admission: dynamic prefill batching ------------------------------
    def admit(self) -> List[TokenEvent]:
        """Prefill waiting requests into free slots.

        Head-of-line requests with equal prompt length are prefilled as one
        batch (dynamic prefill batching); admission stays FIFO.  Prefill
        already yields each request's first generated token.

        The batch is padded to a power-of-two width with duplicates of its
        first row: group width depends on arrival timing, so without
        bucketing each new width is a fresh XLA compile at an unpredictable
        moment.  Padding rows are row-independent (they cannot perturb real
        rows) and are simply not inserted.
        """
        events: List[TokenEvent] = []
        while self.waiting and self.free:
            L = self.waiting[0].prompt.size
            group: List[Request] = []
            while (self.waiting and len(group) < len(self.free)
                   and self.waiting[0].prompt.size == L):
                group.append(self.waiting.popleft())
            width = 1
            while width < len(group):
                width *= 2
            prompts = np.stack([r.prompt for r in group]
                               + [group[0].prompt] * (width - len(group)))
            first, rows = self.engine.prefill(prompts)
            self.stats["prefill_calls"] += 1
            self.stats["prefill_requests"] += len(group)
            self.stats["prefill_padded_rows"] += width - len(group)
            for i, req in enumerate(group):
                slot = self.free.pop()
                req.slot = slot
                self.slots[slot] = req
                self.cache = self.engine.insert_slot(self.cache, rows,
                                                     i, slot)
                self.positions[slot] = req.prompt.size
                events.append(self._record(req, int(first[i])))
            self.stats["max_active_slots"] = max(
                self.stats["max_active_slots"], self.active)
        return events

    # -- one decode step over the slot mask -------------------------------
    def step(self) -> List[TokenEvent]:
        if self.active == 0:
            return []
        active = np.zeros(self.num_slots, bool)
        for slot, req in enumerate(self.slots):
            active[slot] = req is not None
        next_tok, self.cache = self.engine.decode_slots(
            self.cache, self.last_tokens, self.positions, active)
        self.stats["decode_steps"] += 1
        events = []
        for slot in np.nonzero(active)[0]:
            req = self.slots[slot]
            self.positions[slot] += 1
            events.append(self._record(req, int(next_tok[slot])))
        return events

    # -- bookkeeping ------------------------------------------------------
    def _record(self, req: Request, token: int) -> TokenEvent:
        req.tokens.append(token)
        self.last_tokens[req.slot] = token
        index = len(req.tokens) - 1
        if req.eos_id is not None and token == req.eos_id:
            req.finished, req.finish_reason = True, "eos"
            self.stats["evictions_eos"] += 1
        elif len(req.tokens) >= req.max_new_tokens:
            req.finished, req.finish_reason = True, "length"
            self.stats["evictions_length"] += 1
        if req.finished:
            self._evict(req)
        return TokenEvent(req, token, index, req.finished)

    def _evict(self, req: Request) -> None:
        """Free the request's slot.  The cache row is left as-is: a later
        insert overwrites the whole row, and inactive rows cannot perturb
        active ones (row-independent decode)."""
        slot = req.slot
        self.slots[slot] = None
        self.positions[slot] = 0
        self.last_tokens[slot] = self.pad_id
        self.free.append(slot)
        req.slot = -1
        self.stats["completed"] += 1


class PagedScheduler(SlotScheduler):
    """Continuous batching over a paged KV cache.

    Instead of one contiguous max-length cache row per slot, K/V live in
    a block-pool arena (:class:`~repro.serving.kvcache.BlockPool`): each
    request owns a *block table* of fixed-size token pages, allocated as
    its sequence grows and freed on eviction, and full prompt blocks are
    shared across requests by a hash-trie prefix index (ref-counted; a
    prefix hit skips that prefix's prefill compute entirely via the
    prefix-extend path).

    Admission is **block-availability-aware**: a request is admitted only
    once its worst-case page demand ``ceil((S + max_new) / bs)`` (minus
    shared-prefix hits) can be *reserved*, so decode-time page extension
    can never fail mid-flight and no preemption path is needed.  Requests
    beyond block capacity wait, which ultimately surfaces upstream as
    FlowLimiter back-pressure reflecting real memory.

    Greedy decode stays bit-identical to ``LLMEngine.generate``: pages
    gather back into position order (decode) and suffix prefill is
    row-independent (see the model-layer docstrings).
    """

    def __init__(self, engine, num_slots: int = 4, *,
                 num_blocks: int, block_size: int = 16,
                 max_new_tokens: int = 16, eos_id: Optional[int] = None,
                 pad_id: int = 0, prefix_sharing: bool = True,
                 trace=None):
        from .kvcache import BlockPool, PrefixIndex, ROOT
        self._ROOT = ROOT
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        super().__init__(engine, num_slots, max_new_tokens=max_new_tokens,
                         eos_id=eos_id, pad_id=pad_id)
        self.pool = BlockPool(self.num_blocks, self.block_size)
        self.prefix: Optional[PrefixIndex] = \
            PrefixIndex() if prefix_sharing else None
        self.pages_per_seq = engine.max_len // self.block_size
        self.tables = np.zeros((self.num_slots, self.pages_per_seq),
                               np.int32)
        self._trace = trace or (lambda name, value: None)
        self.stats.update({
            "prefill_tokens": 0,          # prompt tokens actually computed
            "prefill_tokens_saved": 0,    # covered by shared prefix blocks
            "shared_block_hits": 0, "extend_prefills": 0,
            "admission_blocked_on_blocks": 0, "blocks_peak": 0,
        })

    def _make_cache(self):
        return self.engine.new_paged_cache(self.num_blocks,
                                           self.block_size)

    def max_request_pages(self) -> int:
        """Largest worst-case page demand the arena can ever satisfy."""
        return self.num_blocks - 1          # block 0 is the trash block

    def submit(self, payload) -> Request:
        req_pages = -(-(np.asarray(payload["tokens"]).size
                        + payload.get("max_new_tokens",
                                      self.default_max_new))
                      // self.block_size)
        if req_pages > self.max_request_pages():
            # admission could never reserve this: without the check the
            # request would sit at the FIFO head forever, starving
            # everything behind it
            raise ValueError(
                f"request {payload.get('id')!r}: needs {req_pages} KV "
                f"blocks but the arena only has "
                f"{self.max_request_pages()} usable blocks")
        return super().submit(payload)

    def _trace_pool(self) -> None:
        self._trace("kvcache.blocks_in_use", self.pool.blocks_in_use)
        self._trace("kvcache.blocks_free", self.pool.free_blocks)

    # -- admission --------------------------------------------------------
    def admit(self) -> List[TokenEvent]:
        """Admit waiting requests while a slot AND their worst-case block
        reservation are available.  Requests are processed one at a time
        so a request can share full prompt blocks registered by the one
        admitted just before it (cold prefills are batch-1; the win moves
        from padding-free grouping to not recomputing shared prefixes)."""
        events: List[TokenEvent] = []
        bs = self.block_size
        while self.waiting and self.free:
            req = self.waiting[0]
            S = req.prompt.size
            total_pages = -(-(S + req.max_new_tokens) // bs)
            if self.prefix is not None:
                hits, parent = self.prefix.match(req.prompt, bs,
                                                 max_blocks=(S - 1) // bs)
            else:
                hits, parent = [], self._ROOT
            need = total_pages - len(hits)
            if not self.pool.can_reserve(need):
                self.stats["admission_blocked_on_blocks"] += 1
                break
            self.waiting.popleft()
            self.pool.reserve(need)
            for b in hits:
                self.pool.ref_inc(b)
            n_prompt_pages = -(-S // bs)
            owned = [self.pool.allocate(reserved=True)
                     for _ in range(n_prompt_pages - len(hits))]
            slot = self.free.pop()
            req.slot = slot
            self.slots[slot] = req
            req.blocks = hits + owned
            req.n_pages = n_prompt_pages
            req.reserved_left = total_pages - n_prompt_pages
            C = len(hits) * bs
            req.prefix_len = C
            self.tables[slot] = 0
            self.tables[slot, :n_prompt_pages] = req.blocks
            page_ids = np.zeros(self.pages_per_seq, np.int32)
            if C:
                first, rows = self.engine.prefill_extend(
                    req.prompt[C:], self.cache, self.tables[slot], C)
                page_ids[:len(owned)] = owned
                self.stats["extend_prefills"] += 1
                self.stats["prefill_tokens"] += S - C
                self.stats["prefill_tokens_saved"] += C
                self.stats["shared_block_hits"] += len(hits)
            else:
                first, rows = self.engine.prefill(req.prompt[None])
                page_ids[:n_prompt_pages] = owned
                self.stats["prefill_tokens"] += S
            self.cache = self.engine.paged_insert(self.cache, rows, 0,
                                                  page_ids)
            self.stats["prefill_calls"] += 1
            self.stats["prefill_requests"] += 1
            if self.prefix is not None:
                key = parent
                for i in range(len(hits), S // bs):
                    key = self.prefix.register(
                        key, req.prompt[i * bs:(i + 1) * bs],
                        req.blocks[i])
            self.positions[slot] = S
            events.append(self._record(req, int(first[0])))
            self.stats["max_active_slots"] = max(
                self.stats["max_active_slots"], self.active)
            self.stats["blocks_peak"] = self.pool.stats["peak_in_use"]
        self._trace_pool()
        return events

    # -- one decode step --------------------------------------------------
    def step(self) -> List[TokenEvent]:
        if self.active == 0:
            return []
        bs = self.block_size
        active = np.zeros(self.num_slots, bool)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            active[slot] = True
            page = int(self.positions[slot]) // bs
            if page >= req.n_pages:
                # the write position crossed into a fresh page: extend the
                # block table from this request's reservation (guaranteed
                # to succeed — that is what admission reserved)
                blk = self.pool.allocate(reserved=True)
                req.reserved_left -= 1
                req.blocks.append(blk)
                self.tables[slot, page] = blk
                req.n_pages += 1
        self.stats["blocks_peak"] = self.pool.stats["peak_in_use"]
        next_tok, self.cache = self.engine.decode_paged(
            self.cache, self.last_tokens, self.positions, active,
            self.tables)
        self.stats["decode_steps"] += 1
        events = []
        for slot in np.nonzero(active)[0]:
            req = self.slots[slot]
            self.positions[slot] += 1
            events.append(self._record(req, int(next_tok[slot])))
        self._trace_pool()
        return events

    # -- eviction ---------------------------------------------------------
    def _evict(self, req: Request) -> None:
        slot = req.slot
        super()._evict(req)
        self.tables[slot] = 0
        for b in req.blocks:
            if self.pool.free(b) and self.prefix is not None:
                self.prefix.unregister_block(b)
        req.blocks = []
        self.pool.release_reservation(req.reserved_left)
        req.reserved_left = 0
