"""Continuous batching: ONE scheduler over the CacheBackend protocol.

This is the core of the GraphServer subsystem (vLLM-style continuous
batching mapped onto the repo's MediaPipe-like graph runtime).  The decode
batch is a fixed set of ``num_slots`` *slots*; each slot holds one
in-flight request's cache row (contiguous) or block table (paged) — the
layout difference lives entirely behind the request's
:class:`~repro.serving.kvcache.CacheBackend`.  The scheduler owns policy:
the priority queue, slot assignment, **chunked prefill** (long prompts
ingested in fixed-token chunks interleaved with decode ticks, so a long
arrival no longer stalls every active request's next token),
**preemption** (when the paged backend runs out of blocks, the
least-important request is evicted and recomputed on readmission) and
**self-speculative decoding** (``speculate_k``: prompt-lookup drafts
verified in one batched pass, ``accepted + 1`` tokens emitted per tick
— docs/SPECULATIVE.md).

Determinism: greedy decode stays bit-identical to
``LLMEngine.generate`` one request at a time under every schedule —
admission order, chunk boundaries, speculative drafts and preemptions
included.  Prefill
batches group only equal-length prompts (no padding perturbs positions),
every decode-batch row op is row-independent, chunked/prefix extension
reproduces exactly the cold prefill's K/V (see the model-layer
docstrings), and a preempted request replays ``prompt ++ tokens[:-1]``
through the same deterministic prefill, re-deriving — and suppressing —
its already-streamed tokens before continuing.

The scheduler here is host-side and graph-agnostic: the MediaPipe wiring
(admission through ``FlowLimiterCalculator``, the tick loopback that lets
the graph scheduler interleave admission with decode steps) lives in
:mod:`repro.serving.calculators` / :mod:`repro.serving.pipeline`.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import functools
import itertools
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .kvcache.backend import CacheBackend, CachePressure
from .speculative import lookup_draft

_EMPTY_DRAFT = np.zeros(0, np.int32)

#: ids whose cancel arrived before the request itself (a CONTROL packet
#: overtaking its REQUEST through the flow limiter) are remembered up to
#: this many entries; older entries age out (a cancel for an id that
#: never arrives — e.g. shed upstream — must not pin memory forever).
_CANCEL_BACKLOG = 1024


class DeadlineExceeded(ValueError):
    """A request's deadline was already expired at submission time.

    Typed (rather than a bare ``ValueError``) so front ends can map it to
    a distinct client-visible rejection without string matching."""


@dataclasses.dataclass(eq=False)
class Request:
    """One generation request as tracked by the scheduler."""
    id: Any
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    priority: int = 0                  # higher value = more important
    arrival: int = 0                   # monotone submission order
    speculate_k: int = 0               # max drafted tokens per decode tick
    # SLO fields (absolute times on the scheduler's clock; None = no SLO)
    deadline: Optional[float] = None        # whole request must finish by
    ttft_deadline: Optional[float] = None   # first token must be out by
    submitted_at: float = 0.0
    admitted_at: Optional[float] = None     # first slot admission
    first_token_at: Optional[float] = None
    last_token_at: Optional[float] = None   # maintained when observing
    # per-request speculative tallies (cheap ints; feed the final
    # per-request metrics record surfaced by the frontend)
    spec_drafted: int = 0
    spec_accepted: int = 0
    cancelled: bool = False            # cancel requested (or applied)
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    ingested: int = 0                  # tokens of `seq` already in cache
    preemptions: int = 0
    finished: bool = False
    finish_reason: str = ""            # "eos" | "length"
    # backend-owned state (paged: block table bookkeeping)
    blocks: List[int] = dataclasses.field(default_factory=list)
    n_pages: int = 0                   # pages present in the block table
    registered: int = 0                # pages published to the prefix index
    reserved_left: int = 0             # reserved-but-unallocated pages
    prefix_len: int = 0                # tokens reused from shared blocks
    prefix_key: Any = None             # prefix-index chain key

    @property
    def seq(self) -> np.ndarray:
        """The token sequence whose K/V must be in cache before this
        request can decode: the prompt, plus — after a preemption —
        every already-emitted token except the last (the last emitted
        token is re-derived by the replay prefill itself, which is what
        proves the recomputation bit-identical)."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens[:-1], np.int32)])

    def sort_key(self):
        return (-self.priority, self.arrival)


@dataclasses.dataclass
class TokenEvent:
    """One generated token (or the request's completion).

    ``token is None`` marks a token-less completion: the request left the
    system by cancellation or a missed deadline instead of generating a
    final token (``request.finish_reason`` says which)."""
    request: Request
    token: Optional[int]
    index: int                          # 0-based position in the generation
    finished: bool


class Scheduler:
    """Admission + chunked prefill + per-step decode over a fixed-width
    slot batch, parameterized by a :class:`CacheBackend`.

    Drive it with::

        sched.submit(payload)      # any number of times, any time
        events = sched.admit()     # admission + one prefill chunk each
        events += sched.step()     # one decode step across active slots

    until :meth:`has_work` is False.  ``admit``/``step`` return
    :class:`TokenEvent` lists in deterministic order.

    ``chunk_size`` enables chunked prefill: a prompt longer than one
    chunk is ingested one chunk per ``admit`` tick while other slots keep
    decoding (the backend aligns the chunk — paged rounds up to a whole
    number of blocks).  ``None`` ingests whole prompts at admission.

    ``speculate_k`` enables self-speculative decoding (the default for
    requests that don't override it): each decode tick drafts up to
    ``k`` continuation tokens by prompt lookup
    (:func:`repro.serving.speculative.lookup_draft`, n-gram size
    ``spec_ngram``), verifies the whole window in one batched forward
    pass, and emits ``accepted + 1`` tokens — bit-identical to plain
    greedy decode under every acceptance pattern (docs/SPECULATIVE.md).
    ``draft_fn(context, k)`` swaps in a custom drafting policy.
    """

    def __init__(self, backend: CacheBackend, *,
                 max_new_tokens: int = 16, eos_id: Optional[int] = None,
                 pad_id: int = 0, chunk_size: Optional[int] = None,
                 speculate_k: int = 0, spec_ngram: int = 3,
                 draft_fn: Optional[Callable[[np.ndarray, int],
                                             np.ndarray]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 trace=None, observer=None):
        engine = backend.engine
        if engine.cfg.is_encoder_decoder:
            raise ValueError("continuous batching supports decoder-only "
                             "models (encoder-decoder prefill needs "
                             "enc_embeds plumbing)")
        self.backend = backend
        self.engine = engine
        self.num_slots = backend.num_slots
        self.default_max_new = int(max_new_tokens)
        self.default_eos = eos_id
        self.pad_id = int(pad_id)
        self.chunk: Optional[int] = None
        if chunk_size is not None:
            engine.check_extend_support(backend.kind)
            self.chunk = backend.align_chunk(chunk_size)
        self.default_spec_k = int(speculate_k)
        self.draft_fn = draft_fn if draft_fn is not None else \
            functools.partial(lookup_draft, max_ngram=int(spec_ngram))
        self._spec_checked = False
        if self.default_spec_k > 0:
            self._check_spec()
        self.clock = clock
        self._has_slo = False          # any live request carries a deadline
        # cancels that arrived before their request (id -> True), capped
        self._cancelled_ids: "collections.OrderedDict[Any, bool]" = \
            collections.OrderedDict()
        self.waiting: List[Request] = []      # sorted by sort_key()
        self.ingesting: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self.free: List[int] = list(range(self.num_slots))  # LIFO reuse
        self.positions = np.zeros(self.num_slots, np.int32)
        self.last_tokens = np.full(self.num_slots, self.pad_id, np.int32)
        self._arrival = itertools.count()
        self.stats: Dict[str, Any] = {
            "submitted": 0, "completed": 0, "decode_steps": 0,
            "prefill_calls": 0, "prefill_requests": 0,
            "prefill_padded_rows": 0,
            "prefill_tokens": 0,          # prompt tokens actually computed
            "extend_prefills": 0, "chunked_prefill_ticks": 0,
            "preemptions": 0, "replayed_tokens": 0,
            "evictions_eos": 0, "evictions_length": 0,
            # speculative decoding: verify ticks, drafted/accepted draft
            # tokens, and tokens emitted on verify ticks (accepted + 1
            # bonus each) — acceptance rate = spec_accepted/spec_drafted
            "spec_steps": 0, "spec_drafted": 0, "spec_accepted": 0,
            "spec_emitted": 0,
            # front-door lifecycle: requests cancelled (client disconnect
            # / explicit cancel) and requests terminated for a missed
            # deadline or TTFT target — both count toward `completed`
            "requests_cancelled": 0, "deadline_missed": 0,
            "max_active_slots": 0,
            # peak requests inside the subsystem (waiting + active): with a
            # FlowLimiter upstream this must never exceed max_in_flight
            "max_outstanding": 0,
        }
        self._trace = trace if trace is not None else \
            (lambda name, value: None)
        # lifecycle observer (serving/observe.py): spans + metrics.  The
        # `_observe` flag gates every clock read the hooks would need, so
        # a NULL_OBSERVER scheduler's hot path stays timing-free.
        from .observe import NULL_OBSERVER
        self.obs = observer if observer is not None else NULL_OBSERVER
        self._observe = bool(self.obs.enabled)
        backend.bind(self.stats, trace)

    def _check_spec(self) -> None:
        if not self._spec_checked:
            self.engine.check_spec_support(self.backend.kind)
            self._spec_checked = True

    # -- backend conveniences (servers, benchmarks, tests) ---------------
    @property
    def pool(self):
        return getattr(self.backend, "pool", None)

    @property
    def prefix(self):
        return getattr(self.backend, "prefix", None)

    # -- state predicates -------------------------------------------------
    @property
    def active(self) -> int:
        return self.num_slots - len(self.free)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.active > 0

    # -- request intake ---------------------------------------------------
    def submit(self, payload: Dict[str, Any]) -> Request:
        """payload: {'tokens': [S] ints, 'id': any, 'max_new_tokens': int?,
        'eos_id': int?, 'priority': int?, 'speculate_k': int?,
        'deadline_ms': float?, 'ttft_ms': float?, 'deadline': float?,
        'ttft_deadline': float?}.
        Validated against the backend's REAL capacity (paged: arena
        blocks, not just engine.max_len) so an unservable request fails
        here instead of starving the queue.

        SLO fields: ``deadline_ms`` / ``ttft_ms`` are relative to now
        (this submit) and raise :class:`DeadlineExceeded` when already
        non-positive — a request that cannot possibly meet its deadline
        is rejected up front rather than admitted to fail.  ``deadline``
        / ``ttft_deadline`` are absolute times on the scheduler's clock
        (used by the GraphServer, which validates at ITS submit time and
        must not crash the graph when time in the admission queue eats
        the budget — that becomes a `deadline_missed`, not an error)."""
        prompt = np.asarray(payload["tokens"], np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        max_new = int(payload.get("max_new_tokens", self.default_max_new))
        cap = self.backend.max_request_tokens()
        if prompt.size + max_new > cap:
            raise ValueError(
                f"request {payload.get('id')!r}: prompt ({prompt.size}) + "
                f"max_new_tokens ({max_new}) exceeds "
                f"{self.backend.capacity_desc()}")
        spec_k = int(payload.get("speculate_k", self.default_spec_k))
        if spec_k < 0:
            raise ValueError(f"request {payload.get('id')!r}: "
                             f"speculate_k must be >= 0, got {spec_k}")
        if spec_k > 0:
            self._check_spec()
        deadline = payload.get("deadline")
        ttft_deadline = payload.get("ttft_deadline")
        now = None
        for rel_key, abs_val in (("deadline_ms", deadline),
                                 ("ttft_ms", ttft_deadline)):
            if payload.get(rel_key) is None:
                continue
            rel = float(payload[rel_key])
            if rel <= 0:
                raise DeadlineExceeded(
                    f"request {payload.get('id')!r}: {rel_key}={rel:g} "
                    f"is already expired at submit")
            now = self.clock() if now is None else now
            if rel_key == "deadline_ms":
                deadline = now + rel / 1e3
            else:
                ttft_deadline = now + rel / 1e3
        req = Request(
            id=payload.get("id"),
            prompt=prompt,
            max_new_tokens=max_new,
            eos_id=payload.get("eos_id", self.default_eos),
            priority=int(payload.get("priority", 0)),
            speculate_k=spec_k,
            deadline=deadline,
            ttft_deadline=ttft_deadline,
            arrival=next(self._arrival))
        req.submitted_at = now if now is not None else self.clock()
        if deadline is not None or ttft_deadline is not None:
            self._has_slo = True
        if self._cancelled_ids.pop(req.id, None):
            # the cancel overtook the request through the admission path:
            # mark it now, the next admit() sweep completes it
            req.cancelled = True
        bisect.insort(self.waiting, req, key=Request.sort_key)
        self.stats["submitted"] += 1
        self.stats["max_outstanding"] = max(
            self.stats["max_outstanding"],
            self.stats["submitted"] - self.stats["completed"])
        if self._observe:
            self.obs.submitted(req, len(self.waiting))
        return req

    # -- cancellation + deadlines -----------------------------------------
    def cancel(self, target: Any) -> List[TokenEvent]:
        """Cancel a request at ANY point of its lifecycle; returns the
        completion event (empty list when there is nothing to cancel).

        ``target`` is a :class:`Request` or a request id.  Semantics per
        state:

        * **waiting / preempted-and-requeued** — dequeued and completed;
          it holds no cache resources (``release`` ran at preemption), so
          nothing else happens.  In particular a preempted-then-cancelled
          request does NOT take another ``preemptions`` count — cancel is
          its own path, never routed through :meth:`preempt`.
        * **active (mid-ingest / mid-decode / between verify ticks)** —
          the backend's :meth:`~repro.serving.kvcache.CacheBackend.cancel`
          seam releases the slot's memory (paged: blocks freed, trie refs
          dropped, reservations returned) and the slot returns to the
          free list.  Scheduler ticks are atomic, so a "mid-verify"
          cancel lands between ticks, when positions/truncate already
          rolled the rejected tail back — abandoning a speculative
          window is always safe.
        * **unknown id** — remembered (bounded backlog) so a cancel that
          overtakes its own request through the admission path still
          lands; the request completes as cancelled at its first
          ``admit`` tick.  A cancel for an id that already finished is a
          no-op beyond that bookkeeping (the post-EOS race).

        Already-streamed tokens stay valid; the completion event carries
        ``token=None`` and ``finish_reason='cancelled'``."""
        req = target if isinstance(target, Request) else self._find(target)
        if req is None:
            self._cancelled_ids[target] = True
            while len(self._cancelled_ids) > _CANCEL_BACKLOG:
                self._cancelled_ids.popitem(last=False)
            return []
        if req.finished:
            return []
        req.cancelled = True
        return [self._finish_empty(req, "cancelled")]

    def _find(self, rid: Any) -> Optional[Request]:
        for r in self.slots:
            if r is not None and r.id == rid:
                return r
        for r in self.waiting:
            if r.id == rid:
                return r
        return None

    def _finish_empty(self, req: Request, reason: str) -> TokenEvent:
        """Terminate ``req`` without a token (cancel / missed deadline),
        releasing whatever it holds."""
        if req.slot >= 0 and self.slots[req.slot] is req:
            if req in self.ingesting:
                self.ingesting.remove(req)
            slot = req.slot
            self.backend.cancel(req)
            self.slots[slot] = None
            self.positions[slot] = 0
            self.last_tokens[slot] = self.pad_id
            self.free.append(slot)
            req.slot = -1
        elif req in self.waiting:
            self.waiting.remove(req)
        req.finished = True
        req.finish_reason = reason
        self.stats["completed"] += 1
        key = "requests_cancelled" if reason == "cancelled" \
            else "deadline_missed"
        self.stats[key] += 1
        self._trace(f"serve.{key}", self.stats[key])
        if self._observe:
            self.obs.finished(req, reason)
        return TokenEvent(req, None, len(req.tokens), True)

    def _lifecycle_sweep(self) -> List[TokenEvent]:
        """Complete pending cancellations and expire missed deadlines —
        runs at the top of every :meth:`admit` tick."""
        events: List[TokenEvent] = []
        for req in [r for r in self.waiting if r.cancelled]:
            events.append(self._finish_empty(req, "cancelled"))
        if not self._has_slo:
            return events
        now = self.clock()
        live = [r for r in self.waiting] + \
               [r for r in self.slots if r is not None]
        for req in live:
            if req.finished:
                continue
            missed = (req.deadline is not None and now >= req.deadline) \
                or (req.first_token_at is None
                    and req.ttft_deadline is not None
                    and now >= req.ttft_deadline)
            if missed:
                events.append(self._finish_empty(req, "deadline"))
        return events

    def _slo_preempt(self) -> bool:
        """SLO-aware admission: when no slot is free, a waiting request
        with a TTFT target may preempt a strictly-lower-priority active
        request (lowest priority, youngest arrival — same victim rule as
        cache pressure).  Equal priority never preempts, so plain
        priority admission keeps its no-preemption behaviour."""
        if self.free or not self.waiting:
            return bool(self.free)
        head = self.waiting[0]
        if head.ttft_deadline is None:
            return False
        candidates = [r for r in self.slots if r is not None]
        if not candidates:
            return False
        victim = min(candidates, key=lambda r: (r.priority, -r.arrival))
        if victim.priority >= head.priority:
            return False
        self._preempt(victim)
        return True

    # -- admission + chunked prefill --------------------------------------
    def admit(self) -> List[TokenEvent]:
        """Admit waiting requests into free slots and advance prompt
        ingestion by (at most) one chunk per in-flight request.

        Requests whose whole prompt fits one chunk are prefilled as one
        batch per equal prompt length when the backend supports it
        (dynamic prefill batching; padding rows are row-independent).
        Otherwise each newly-admitted request ingests its first chunk
        immediately — one at a time, so a request can share prefix
        blocks registered by the one admitted just before it.

        Before admission the tick sweeps lifecycle state: pending
        cancellations complete (resources released), expired deadlines
        and missed TTFT targets terminate their requests, and a waiting
        request with a TTFT target may preempt a strictly-lower-priority
        active request when no slot is free (SLO-aware admission — the
        deadline feeds the same priority+preemption machinery pressure
        uses)."""
        events: List[TokenEvent] = self._lifecycle_sweep()
        # continue in-flight chunked ingests first (FIFO fairness)
        for req in list(self.ingesting):
            events.extend(self._ingest_tick(req))
        group: List[Request] = []
        while self.waiting and (self.free or self._slo_preempt()):
            req = self.waiting[0]
            if not self.backend.can_admit(req, req.seq, self.chunk):
                break
            self.waiting.pop(0)
            slot = self.free.pop()
            req.slot = slot
            self.slots[slot] = req
            self.backend.acquire(req, req.seq)
            req.ingested = req.prefix_len
            self.positions[slot] = req.ingested
            self.ingesting.append(req)
            self.stats["max_active_slots"] = max(
                self.stats["max_active_slots"], self.active)
            if self._observe:
                first_admission = req.admitted_at is None
                if first_admission:
                    req.admitted_at = self.clock()
                # queue wait counts only the initial submit->slot wait;
                # readmissions after preemption still get their span
                self.obs.admitted(
                    req, (req.admitted_at - req.submitted_at) * 1e3
                    if first_admission else None)
            if (self.backend.supports_group_prefill and not req.tokens
                    and req.ingested == 0
                    and (self.chunk is None
                         or req.prompt.size <= self.chunk)):
                group.append(req)
            else:
                events.extend(self._ingest_tick(req))
        if group:
            events.extend(self._group_prefill(group))
        return events

    def _group_prefill(self, reqs: List[Request]) -> List[TokenEvent]:
        """Whole-prompt batch prefill, one call per distinct length."""
        events: List[TokenEvent] = []
        by_len: Dict[int, List[Request]] = {}
        for r in reqs:
            by_len.setdefault(int(r.prompt.size), []).append(r)
        for grp in sorted(by_len.values(), key=lambda g: g[0].arrival):
            t0 = self.obs.now() if self._observe else 0.0
            first = self.backend.prefill_group(grp)
            if self._observe:
                self.obs.prefill((self.obs.now() - t0) * 1e3,
                                 sum(int(r.prompt.size) for r in grp))
            for i, req in enumerate(grp):
                self.ingesting.remove(req)
                req.ingested = req.prompt.size
                self.positions[req.slot] = req.prompt.size
                self.stats["prefill_requests"] += 1
                events.append(self._record(req, int(first[i])))
        return events

    def _ingest_tick(self, req: Request) -> List[TokenEvent]:
        """Ingest the next chunk of ``req``'s sequence, preempting under
        cache pressure.  Emits the first generated token when ingestion
        completes (suppressed on a post-preemption replay: the re-derived
        token was already streamed)."""
        if req not in self.ingesting:      # preempted earlier this round
            return []
        seq = req.seq
        start = req.ingested
        end = len(seq) if self.chunk is None \
            else min(len(seq), start + self.chunk)
        while True:
            try:
                t0 = self.obs.now() if self._observe else 0.0
                tok = self.backend.ingest(req, seq, start, end)
                if self._observe:
                    self.obs.chunk(req, start, end,
                                   (self.obs.now() - t0) * 1e3)
                break
            except CachePressure:
                if self._observe:
                    self.obs.pressure(req)
                victim = self._pick_victim()
                self._preempt(victim)
                if victim is req:
                    return []
        if self.chunk is not None and (end < len(seq)
                                       or start > req.prefix_len):
            self.stats["chunked_prefill_ticks"] += 1
        req.ingested = end
        if end < len(seq):
            # Mid-ingest slots are outside the decode mask, but a decode
            # step still WRITES at positions[slot] for every row (row ops
            # are row-independent, not row-skipping).  Keeping the
            # position at the ingest frontier makes that stray write
            # harmless: the slot layout overwrites the frontier with the
            # next chunk, and the paged layout's frontier page is not in
            # the block table yet, so the write routes to trash block 0.
            self.positions[req.slot] = end
            return []
        self.ingesting.remove(req)
        self.positions[req.slot] = len(seq)
        self.stats["prefill_requests"] += 1
        if req.tokens:
            # replay after preemption: `tok` re-derives the request's
            # last already-emitted token (deterministic greedy decode),
            # so it is not a new event.  A mismatch means the
            # determinism contract is broken (a bug, or a backend whose
            # reduction order varies with batch shape) — continuing
            # would silently stream tokens inconsistent with what the
            # client already received, so fail loudly instead (explicit
            # raise: an assert would vanish under `python -O`).
            if tok != req.tokens[-1]:
                raise RuntimeError(
                    f"request {req.id!r}: replay after preemption "
                    f"re-derived token {tok} where {req.tokens[-1]} was "
                    f"already streamed — determinism contract broken")
            self.last_tokens[req.slot] = req.tokens[-1]
            self.stats["replayed_tokens"] += len(req.tokens)
            if self._observe:
                self.obs.replayed(req, len(req.tokens))
            return []
        return [self._record(req, int(tok))]

    # -- one decode step over the slot mask -------------------------------
    def _decoding(self) -> List[Request]:
        return [r for r in self.slots
                if r is not None and r not in self.ingesting]

    def step(self) -> List[TokenEvent]:
        if not self._decoding():
            return []
        drafts = self._make_drafts()
        # back every write position with memory, preempting if needed;
        # a speculating row backs its whole kept window [pos, pos+|draft|]
        # (the +1 bonus token is emitted but not written this tick)
        for req in list(self._decoding()):
            if req.slot < 0 or self.slots[req.slot] is not req:
                continue                    # preempted by an earlier grow
            lo = int(self.positions[req.slot])
            for p in range(lo, lo + drafts.get(req, _EMPTY_DRAFT).size + 1):
                while (req.slot >= 0 and self.slots[req.slot] is req
                       and not self.backend.grow(req, p)):
                    self._preempt(self._pick_victim())
                if req.slot < 0 or self.slots[req.slot] is not req:
                    break
        active = np.zeros(self.num_slots, bool)
        for req in self._decoding():
            active[req.slot] = True
        if not active.any():
            return []
        drafts = {r: d for r, d in drafts.items()
                  if r.slot >= 0 and self.slots[r.slot] is r}
        if drafts:
            return self._verify_tick(drafts, active)
        t0 = self.obs.now() if self._observe else 0.0
        next_tok = self.backend.decode(self.last_tokens, self.positions,
                                       active)
        if self._observe:
            self.obs.decode_tick((self.obs.now() - t0) * 1e3,
                                 int(active.sum()))
        self.stats["decode_steps"] += 1
        events = []
        for slot in np.nonzero(active)[0]:
            req = self.slots[slot]
            self.positions[slot] += 1
            events.append(self._record(req, int(next_tok[slot])))
        return events

    # -- speculative decoding ---------------------------------------------
    def _make_drafts(self) -> Dict[Request, np.ndarray]:
        """Draft continuation tokens for every speculating decode row.
        Empty dict = plain decode tick (nobody speculates, nobody pays)."""
        decoding = self._decoding()
        if not any(r.speculate_k > 0 for r in decoding):
            return {}
        # The verify window writes at EVERY occupied slot's frontier
        # (row ops are row-independent, not row-skipping), so the batch
        # window must stay inside every row's cache bounds — clamp the
        # draft budget to the most-advanced frontier.  Free slots sit at
        # position 0 and cannot bind tighter.
        frontier = max(int(self.positions[r.slot]) for r in self.slots
                       if r is not None)
        # the backend owns the clamp: cache geometry everywhere, plus the
        # state/hybrid layouts' spec_window (their verify materializes a
        # per-position state stack — the window is a memory budget)
        cap = self.backend.spec_window_cap(frontier)
        drafts: Dict[Request, np.ndarray] = {}
        for r in decoding:
            # remaining - 1: the window emits at most |draft| + 1 tokens,
            # which must not overshoot the request's max_new_tokens
            k = min(r.speculate_k,
                    r.max_new_tokens - len(r.tokens) - 1, cap)
            if k <= 0:
                continue
            ctx = np.concatenate([r.prompt,
                                  np.asarray(r.tokens, np.int32)])
            d = np.asarray(self.draft_fn(ctx, k), np.int32).reshape(-1)
            if d.size:
                drafts[r] = d[:k]
        return drafts

    def _verify_tick(self, drafts: Dict[Request, np.ndarray],
                     active: np.ndarray) -> List[TokenEvent]:
        """One speculative decode tick: score every row's window (last
        emitted token ++ draft, padded to the batch-wide width) in one
        forward pass, accept each row's longest drafted prefix matching
        the greedy argmax chain, emit ``accepted + 1`` tokens per row,
        and roll back the rejected tail (rewind ``positions``; paged
        backends also free now-empty tail blocks via ``truncate``)."""
        K = max(d.size for d in drafts.values())
        window = np.full((self.num_slots, K + 1), self.pad_id, np.int32)
        window[:, 0] = self.last_tokens
        for r, d in drafts.items():
            window[r.slot, 1:1 + d.size] = d
        t0 = self.obs.now() if self._observe else 0.0
        guess = self.backend.verify(window, self.positions, active)
        if self._observe:
            self.obs.verify_tick((self.obs.now() - t0) * 1e3,
                                 int(active.sum()))
        self.stats["decode_steps"] += 1
        self.stats["spec_steps"] += 1
        events: List[TokenEvent] = []
        drafted = accepted = emitted = 0
        for slot in np.nonzero(active)[0]:
            req = self.slots[slot]
            d = drafts.get(req, _EMPTY_DRAFT)
            g = guess[slot]
            a = 0
            while a < d.size and int(d[a]) == int(g[a]):
                a += 1
            drafted += int(d.size)
            accepted += a
            req.spec_drafted += int(d.size)
            req.spec_accepted += a
            if self._observe:
                self.obs.verified(req, a, int(d.size), len(req.tokens))
            pos0 = int(self.positions[slot])
            # g[i] is the greedy token after ...··t0·d[0..i-1]; emitting
            # g[0..a] therefore reproduces exactly what a+1 plain decode
            # steps would have emitted (g[i] == d[i] for i < a)
            for i in range(a + 1):
                events.append(self._record(req, int(g[i])))
                emitted += 1
                if req.finished:        # EOS / length: drop the rest
                    break
            if req.finished:
                continue                # _evict released slot + memory
            self.positions[slot] = pos0 + a + 1
            self.backend.truncate(req, pos0 + a + 1)
        self.stats["spec_drafted"] += drafted
        self.stats["spec_accepted"] += accepted
        self.stats["spec_emitted"] += emitted
        if drafted:
            self._trace("spec.acceptance_pct",
                        int(round(100 * accepted / drafted)))
        self._trace("spec.tokens_per_tick", emitted)
        return events

    # -- preemption -------------------------------------------------------
    def _pick_victim(self) -> Request:
        """Lowest priority first, youngest arrival as tie-break: the
        oldest/most-important requests keep their blocks, which
        guarantees forward progress."""
        candidates = [r for r in self.slots if r is not None]
        return min(candidates, key=lambda r: (r.priority, -r.arrival))

    def _preempt(self, victim: Request) -> None:
        """Evict ``victim`` and requeue it: its blocks are freed, its
        cache is gone, and readmission recomputes ``victim.seq`` through
        the normal (chunked) ingest path — deterministic greedy decode
        makes the recomputation bit-identical, so its output stream just
        pauses and resumes."""
        self.preempt(victim)

    def preempt(self, victim: Request) -> None:
        """Public for tests/tools: force-preempt an in-flight request."""
        if victim.slot < 0 or self.slots[victim.slot] is not victim:
            raise ValueError(f"request {victim.id!r} holds no slot")
        slot = victim.slot
        self.backend.release(victim)
        self.slots[slot] = None
        self.free.append(slot)
        self.positions[slot] = 0
        self.last_tokens[slot] = self.pad_id
        victim.slot = -1
        victim.ingested = 0
        victim.preemptions += 1
        self.stats["preemptions"] += 1
        if self._observe:
            self.obs.preempted(victim)
        if victim in self.ingesting:
            self.ingesting.remove(victim)
        bisect.insort(self.waiting, victim, key=Request.sort_key)

    # -- bookkeeping ------------------------------------------------------
    def _record(self, req: Request, token: int) -> TokenEvent:
        req.tokens.append(token)
        self.last_tokens[req.slot] = token
        index = len(req.tokens) - 1
        if req.first_token_at is None:
            req.first_token_at = self.clock()
            ttft_ms = (req.first_token_at - req.submitted_at) * 1e3
            self._trace("serve.ttft_ms", int(ttft_ms))
            if self._observe:
                req.last_token_at = req.first_token_at
                self.obs.first_token(req, ttft_ms, index)
        elif self._observe:
            now = self.clock()
            prev = req.last_token_at if req.last_token_at is not None \
                else req.first_token_at
            self.obs.token(req, index, (now - prev) * 1e3)
            req.last_token_at = now
        if req.eos_id is not None and token == req.eos_id:
            req.finished, req.finish_reason = True, "eos"
            self.stats["evictions_eos"] += 1
        elif len(req.tokens) >= req.max_new_tokens:
            req.finished, req.finish_reason = True, "length"
            self.stats["evictions_length"] += 1
        if req.finished:
            self._evict(req)
            if self._observe:
                self.obs.finished(req, req.finish_reason)
        return TokenEvent(req, token, index, req.finished)

    def request_metrics(self, req: Request) -> Dict[str, Any]:
        """The final per-request metrics record (surfaced to streaming
        clients on the last TOKEN packet — docs/OBSERVABILITY.md)."""
        m: Dict[str, Any] = {
            "id": req.id, "finish_reason": req.finish_reason,
            "tokens": len(req.tokens),
            "prompt_tokens": int(req.prompt.size),
            "preemptions": req.preemptions,
            "spec_drafted": req.spec_drafted,
            "spec_accepted": req.spec_accepted,
            "ttft_ms": None, "queue_wait_ms": None,
        }
        if req.first_token_at is not None:
            m["ttft_ms"] = (req.first_token_at - req.submitted_at) * 1e3
        if req.admitted_at is not None:
            m["queue_wait_ms"] = \
                (req.admitted_at - req.submitted_at) * 1e3
        return m

    def debug_state(self) -> Dict[str, Any]:
        """Sanitized scheduler state for flight-recorder postmortems: no
        arrays, no backend handles — just the control-plane picture."""
        def info(r: Request) -> Dict[str, Any]:
            return {"id": str(r.id), "priority": r.priority,
                    "arrival": r.arrival, "slot": r.slot,
                    "prompt_len": int(r.prompt.size),
                    "ingested": r.ingested, "tokens": len(r.tokens),
                    "max_new_tokens": r.max_new_tokens,
                    "preemptions": r.preemptions,
                    "cancelled": r.cancelled, "finished": r.finished,
                    "finish_reason": r.finish_reason}
        return {
            "slots": [None if r is None else info(r) for r in self.slots],
            "waiting": [info(r) for r in self.waiting],
            "ingesting": [str(r.id) for r in self.ingesting],
            "free": sorted(self.free),
            "positions": [int(p) for p in self.positions],
            "stats": dict(self.stats),
            "mesh": self.engine.mesh_desc,
        }

    def _evict(self, req: Request) -> None:
        """Free the request's slot and backend resources.  Slot cache
        rows are left as-is: a later insert overwrites the whole row, and
        inactive rows cannot perturb active ones (row-independent
        decode)."""
        slot = req.slot
        self.backend.release(req)
        self.slots[slot] = None
        self.positions[slot] = 0
        self.last_tokens[slot] = self.pad_id
        self.free.append(slot)
        req.slot = -1
        self.stats["completed"] += 1
