"""Request-lifecycle observability: spans, metrics, flight recorder.

Three pieces (docs/OBSERVABILITY.md):

* :class:`Observer` — the seam between the :class:`Scheduler` and the
  telemetry sinks.  Every lifecycle transition (submitted → admitted →
  per-chunk prefill → first token → decode/verify ticks → preempted /
  replayed → finished) lands as a SPAN event in the graph's lock-free
  :class:`~repro.core.tracer.Tracer` ring AND as counters/histograms in
  a :class:`~repro.core.metrics.MetricsRegistry`.  Under
  ``repro.core.tracer.COMPILED_OUT`` the scheduler holds
  :data:`NULL_OBSERVER` instead (``enabled`` False), so the hot path
  carries no clock reads at all — the cost is measured, not assumed, by
  the ``observability`` section of ``benchmarks/serve_bench.py``.

* :class:`RequestTimeline` — reconstructs per-request lifecycles from
  the SPAN events: one Perfetto track per request
  (:meth:`RequestTimeline.export_perfetto`) plus a JSON lifecycle
  record per request (:meth:`RequestTimeline.records`) answering "why
  was THIS request's TTFT 40ms".

* :class:`FlightRecorder` — on an incident (``cache_pressure``,
  ``preemption``, ``deadline_miss``, ``executor_error``) dumps the
  last-N trace events + a metrics snapshot + sanitized scheduler state
  into a provenance-stamped run directory
  (``launch/serve.py --observe-dir``), rate-limited so pressure storms
  don't flood the disk.

SPAN encoding (fits the existing :class:`TraceEvent` tuple unchanged):
``stream_id = "<phase>@<request_id>"``, ``packet_timestamp`` a
phase-specific sequence number (token index, chunk start, ...),
``packet_data_id`` a phase-specific value (accepted count, slot, ...).
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Callable, Dict, List, Optional

from ..core import tracer as trace_mod
from ..core.metrics import MetricsRegistry, NullRegistry

# Lifecycle phases, in nominal order.  "finished" carries the reason as
# "finished:<reason>" (eos | length | cancelled | deadline).
PHASES = ("submitted", "admitted", "chunk", "first_token", "token",
          "verify", "preempted", "replayed", "finished")


def span_id(phase: str, rid: Any) -> str:
    return f"{phase}@{rid}"


def parse_span(stream_id: str):
    """``"<phase>@<rid>" -> (phase, rid_str)`` — phase may carry a
    ``:detail`` suffix (``finished:eos``)."""
    phase, _, rid = stream_id.partition("@")
    return phase, rid


class Observer:
    """Telemetry sink for one scheduler: spans into the tracer ring,
    aggregates into a metrics registry, incidents into a recorder."""

    enabled = True

    def __init__(self, tracer=None, registry: Optional[MetricsRegistry] = None,
                 node_id: int = -1):
        self.tracer = tracer if tracer is not None else trace_mod.NullTracer()
        self.registry = registry if registry is not None else MetricsRegistry()
        self.node_id = int(node_id)
        self.recorder: Optional["FlightRecorder"] = None
        self.now: Callable[[], float] = time.perf_counter
        reg = self.registry
        # -- instruments (pre-bound so hooks don't do name lookups) -------
        self._h_ttft = reg.histogram(
            "serve.ttft_ms", "submit to first token, scheduler-side (ms)")
        self._h_itl = reg.histogram(
            "serve.itl_ms", "gap between consecutive tokens of one "
            "request, scheduler-side (ms)")
        self._h_queue = reg.histogram(
            "serve.queue_wait_ms", "submit to slot admission (ms)")
        self._h_decode = reg.histogram(
            "serve.decode_step_ms", "one batched decode step (ms)")
        self._h_verify = reg.histogram(
            "serve.verify_step_ms", "one speculative verify pass (ms)")
        self._h_prefill = reg.histogram(
            "serve.prefill_ms", "one prefill/ingest backend call (ms)")
        self._h_occupancy = reg.histogram(
            "serve.batch_occupancy", "active decode rows per step")
        self._h_accept = reg.histogram(
            "serve.spec_accepted_per_tick", "accepted draft tokens per "
            "verify tick")
        self._c_submitted = reg.counter(
            "serve.requests_submitted", "requests entering the scheduler")
        self._c_finished = reg.counter(
            "serve.requests_finished", "requests leaving, by reason")
        self._c_tokens = reg.counter(
            "serve.tokens_emitted", "generated tokens streamed out")
        self._c_preempt = reg.counter(
            "serve.preemptions", "victim evictions (pressure or SLO)")
        self._c_replayed = reg.counter(
            "serve.replayed_tokens", "tokens recomputed on readmission")
        self._c_pressure = reg.counter(
            "serve.cache_pressure", "CachePressure events during ingest")
        self._g_waiting = reg.gauge(
            "serve.waiting", "requests queued for admission")
        self._g_mesh_devices = reg.gauge(
            "serve.mesh_devices", "devices in the serving mesh (1 when "
            "unsharded)")
        self._g_mesh_model = reg.gauge(
            "serve.mesh_model", "tensor-parallel (model-axis) size of "
            "the serving mesh")
        self.mesh: Dict[str, Any] = {"devices": 1, "axes": {}}
        self._g_mesh_devices.set(1)
        self._g_mesh_model.set(1)

    def set_mesh(self, desc: Dict[str, Any]) -> None:
        """Tag this observer's metrics with the serving-mesh shape
        (docs/SHARDING.md).  Called once by the engine calculator after
        it learns the engine's mesh — every later metrics snapshot and
        flight-recorder incident carries the shape, so a postmortem from
        a tp=4 run is distinguishable from a single-chip one."""
        self.mesh = dict(desc)
        self._g_mesh_devices.set(int(desc.get("devices", 1)))
        self._g_mesh_model.set(int(desc.get("axes", {}).get("model", 1)))

    # -- span primitive ---------------------------------------------------
    def span(self, phase: str, rid: Any, seq: int = 0, value: int = 0) -> None:
        self.tracer.record(trace_mod.SPAN, self.node_id,
                           span_id(phase, rid), int(seq), int(value))

    # -- scheduler lifecycle hooks ---------------------------------------
    def submitted(self, req, waiting: int) -> None:
        self._c_submitted.inc()
        self._g_waiting.set(waiting)
        self.span("submitted", req.id, seq=int(req.prompt.size),
                  value=req.priority)

    def admitted(self, req, wait_ms: Optional[float]) -> None:
        if wait_ms is not None:      # None = readmission after preemption
            self._h_queue.observe(wait_ms)
        self.span("admitted", req.id, seq=req.preemptions, value=req.slot)

    def prefill(self, dur_ms: float, tokens: int) -> None:
        self._h_prefill.observe(dur_ms)

    def chunk(self, req, start: int, end: int, dur_ms: float) -> None:
        self._h_prefill.observe(dur_ms)
        self.span("chunk", req.id, seq=start, value=end - start)

    def first_token(self, req, ttft_ms: float, index: int = 0) -> None:
        self._h_ttft.observe(ttft_ms)
        self._c_tokens.inc()
        self.span("first_token", req.id, seq=index, value=int(ttft_ms))

    def token(self, req, index: int, itl_ms: float) -> None:
        self._h_itl.observe(itl_ms)
        self._c_tokens.inc()
        self.span("token", req.id, seq=index)

    def decode_tick(self, dur_ms: float, occupancy: int) -> None:
        self._h_decode.observe(dur_ms)
        self._h_occupancy.observe(occupancy)

    def verify_tick(self, dur_ms: float, occupancy: int) -> None:
        self._h_verify.observe(dur_ms)
        self._h_occupancy.observe(occupancy)

    def verified(self, req, accepted: int, drafted: int, seq: int) -> None:
        self._h_accept.observe(accepted)
        self.span("verify", req.id, seq=seq, value=accepted)

    def preempted(self, req) -> None:
        self._c_preempt.inc()
        self.span("preempted", req.id, seq=len(req.tokens),
                  value=req.preemptions)
        if self.recorder is not None:
            self.recorder.incident(
                "preemption", f"request {req.id!r} evicted "
                f"(preemption #{req.preemptions})")

    def replayed(self, req, n_tokens: int) -> None:
        self._c_replayed.inc(n_tokens)
        self.span("replayed", req.id, seq=n_tokens)

    def pressure(self, req) -> None:
        self._c_pressure.inc()
        self.span("pressure", req.id, seq=req.ingested)
        if self.recorder is not None:
            self.recorder.incident(
                "cache_pressure", f"ingest of request {req.id!r} hit "
                f"CachePressure at {req.ingested} tokens")

    def finished(self, req, reason: str) -> None:
        self._c_finished.inc(reason=reason)
        self.span(f"finished:{reason}", req.id, seq=len(req.tokens))
        if reason == "deadline" and self.recorder is not None:
            self.recorder.incident(
                "deadline_miss", f"request {req.id!r} missed its deadline "
                f"after {len(req.tokens)} tokens")


class _NullObserver(Observer):
    """Every hook a no-op; ``enabled`` False lets the scheduler skip the
    clock reads that would feed the hooks."""

    enabled = False

    def __init__(self):
        self.tracer = trace_mod.NullTracer()
        self.registry = NullRegistry()
        self.node_id = -1
        self.recorder = None
        self.now = time.perf_counter
        self.mesh = {"devices": 1, "axes": {}}

    def set_mesh(self, *a, **k):
        pass

    def span(self, *a, **k):
        pass

    def submitted(self, *a, **k):
        pass

    def admitted(self, *a, **k):
        pass

    def prefill(self, *a, **k):
        pass

    def chunk(self, *a, **k):
        pass

    def first_token(self, *a, **k):
        pass

    def token(self, *a, **k):
        pass

    def decode_tick(self, *a, **k):
        pass

    def verify_tick(self, *a, **k):
        pass

    def verified(self, *a, **k):
        pass

    def preempted(self, *a, **k):
        pass

    def replayed(self, *a, **k):
        pass

    def pressure(self, *a, **k):
        pass

    def finished(self, *a, **k):
        pass


NULL_OBSERVER = _NullObserver()


# ---------------------------------------------------------------------------
# Timeline reconstruction
# ---------------------------------------------------------------------------

#: phase -> label of the segment it OPENS on the request's track
_SEGMENT_AFTER = {"submitted": "queued", "admitted": "prefill",
                  "first_token": "decode", "preempted": "requeued"}
_INSTANT_PHASES = {"chunk", "verify", "preempted", "replayed", "pressure",
                   "token"}


class RequestTimeline:
    """Per-request lifecycle reconstruction from SPAN events.

    Build with :meth:`from_tracer` (or from a loaded trace file's
    events); render with :meth:`records` (JSON lifecycle dicts) or
    :meth:`export_perfetto` (one named track per request).
    """

    def __init__(self, events: List[trace_mod.TraceEvent]):
        self._by_req: Dict[str, List[trace_mod.TraceEvent]] = {}
        for e in events:
            if e.event_type != trace_mod.SPAN:
                continue
            phase, rid = parse_span(e.stream_id)
            if not rid:
                continue
            self._by_req.setdefault(rid, []).append(e)
        for evs in self._by_req.values():
            evs.sort(key=lambda e: e.event_time)

    @classmethod
    def from_tracer(cls, tracer) -> "RequestTimeline":
        return cls(tracer.events())

    def request_ids(self) -> List[str]:
        return sorted(self._by_req)

    # -- JSON lifecycle records ------------------------------------------
    def records(self) -> List[Dict[str, Any]]:
        out = []
        for rid in self.request_ids():
            evs = self._by_req[rid]
            rec: Dict[str, Any] = {
                "id": rid, "finish_reason": None,
                "submitted_ms": None, "admitted_ms": None,
                "first_token_ms": None, "finished_ms": None,
                "queue_wait_ms": None, "ttft_ms": None, "total_ms": None,
                "tokens": 0, "chunks": 0, "verify_ticks": 0,
                "accepted_total": 0, "preemptions": 0,
                "replayed_tokens": 0, "pressure_events": 0,
                "events": [],
            }
            for e in evs:
                phase, _ = parse_span(e.stream_id)
                t_ms = e.event_time / 1e6
                base, _, detail = phase.partition(":")
                rec["events"].append({"t_ms": t_ms, "phase": phase,
                                      "seq": e.packet_timestamp,
                                      "value": e.packet_data_id})
                if base == "submitted" and rec["submitted_ms"] is None:
                    rec["submitted_ms"] = t_ms
                elif base == "admitted" and rec["admitted_ms"] is None:
                    rec["admitted_ms"] = t_ms
                elif base == "first_token":
                    rec["first_token_ms"] = t_ms
                    rec["tokens"] += 1
                elif base == "token":
                    rec["tokens"] += 1
                elif base == "chunk":
                    rec["chunks"] += 1
                elif base == "verify":
                    rec["verify_ticks"] += 1
                    rec["accepted_total"] += e.packet_data_id
                elif base == "preempted":
                    rec["preemptions"] += 1
                elif base == "replayed":
                    rec["replayed_tokens"] += e.packet_timestamp
                elif base == "pressure":
                    rec["pressure_events"] += 1
                elif base == "finished":
                    rec["finished_ms"] = t_ms
                    rec["finish_reason"] = detail or "unknown"
            if rec["submitted_ms"] is not None:
                if rec["admitted_ms"] is not None:
                    rec["queue_wait_ms"] = \
                        rec["admitted_ms"] - rec["submitted_ms"]
                if rec["first_token_ms"] is not None:
                    rec["ttft_ms"] = \
                        rec["first_token_ms"] - rec["submitted_ms"]
                if rec["finished_ms"] is not None:
                    rec["total_ms"] = \
                        rec["finished_ms"] - rec["submitted_ms"]
            out.append(rec)
        return out

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"requests": self.records()}, f, indent=2,
                      sort_keys=True)

    # -- Perfetto export --------------------------------------------------
    def export_perfetto(self, path: str, pid: int = 1) -> None:
        """One track (tid) per request: X slices for the lifecycle
        segments (queued / prefill / decode / requeued), instants for
        chunk ingests, verify ticks, preemptions and replays."""
        out: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": pid,
             "args": {"name": "requests"}}]
        for tid, rid in enumerate(self.request_ids()):
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": f"req {rid}"}})
            seg_label: Optional[str] = None
            seg_t0 = 0.0
            for e in self._by_req[rid]:
                phase, _ = parse_span(e.stream_id)
                base, _, detail = phase.partition(":")
                ts_us = e.event_time / 1e3
                closes = base in _SEGMENT_AFTER or base == "finished"
                if closes and seg_label is not None:
                    out.append({"ph": "X", "pid": pid, "tid": tid,
                                "name": seg_label, "cat": "lifecycle",
                                "ts": seg_t0, "dur": ts_us - seg_t0,
                                "args": {}})
                    seg_label = None
                if base in _SEGMENT_AFTER:
                    seg_label = _SEGMENT_AFTER[base]
                    seg_t0 = ts_us
                if base in _INSTANT_PHASES or base == "finished":
                    out.append({"ph": "i", "s": "t", "pid": pid,
                                "tid": tid, "name": phase,
                                "cat": "lifecycle", "ts": ts_us,
                                "args": {"seq": e.packet_timestamp,
                                         "value": e.packet_data_id}})
        with open(path, "w") as f:
            json.dump({"traceEvents": out, "displayTimeUnit": "ms"}, f)


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------

def run_provenance(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    """Best-effort provenance stamp (git sha, interpreter, argv, time)."""
    sha = None
    try:
        import subprocess
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        pass
    return {
        "git_sha": sha,
        "python": sys.version.split()[0],
        "argv": list(sys.argv if argv is None else argv),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


class FlightRecorder:
    """Dumps a postmortem artifact per incident into ``out_dir``.

    Rate limiting: at most ``max_dumps`` incidents per run and at most
    one per ``min_interval_s`` per trigger kind (a pressure storm during
    a long ingest would otherwise write hundreds of identical files);
    suppressed incidents are counted, not lost silently.
    """

    TRIGGERS = ("cache_pressure", "preemption", "deadline_miss",
                "executor_error")

    def __init__(self, out_dir: str, *, last_n: int = 512,
                 max_dumps: int = 8, min_interval_s: float = 1.0,
                 registry: Optional[MetricsRegistry] = None,
                 mesh: Optional[Dict[str, Any]] = None):
        self.out_dir = out_dir
        self.last_n = int(last_n)
        self.max_dumps = int(max_dumps)
        self.min_interval_s = float(min_interval_s)
        # serving-mesh shape (docs/SHARDING.md) — stamped into every
        # incident so multi-device postmortems identify their topology
        self.mesh = dict(mesh) if mesh is not None else \
            {"devices": 1, "axes": {}}
        self._dumps = 0
        self._last_by_trigger: Dict[str, float] = {}
        self._events_fn: Callable[[], list] = list
        self._metrics_fn: Callable[[], dict] = dict
        self._state_fn: Callable[[], dict] = dict
        self._provenance = run_provenance()
        reg = registry if registry is not None else NullRegistry()
        self._c_dumps = reg.counter(
            "observe.flight_dumps", "incident files written")
        self._c_suppressed = reg.counter(
            "observe.flight_dumps_suppressed",
            "incidents skipped by rate limiting")

    def bind(self, *, events_fn=None, metrics_fn=None, state_fn=None) -> None:
        """Late-bind the snapshot providers (the scheduler exists only
        after the graph opens its engine node)."""
        if events_fn is not None:
            self._events_fn = events_fn
        if metrics_fn is not None:
            self._metrics_fn = metrics_fn
        if state_fn is not None:
            self._state_fn = state_fn

    @property
    def incident_dir(self) -> str:
        return os.path.join(self.out_dir, "incidents")

    def incident(self, trigger: str, detail: str = "") -> Optional[str]:
        """Write one postmortem file; returns its path (None when rate
        limited or on a write failure — an incident dump must never take
        the serving path down with it)."""
        now = time.monotonic()
        last = self._last_by_trigger.get(trigger)
        if self._dumps >= self.max_dumps or (
                last is not None and now - last < self.min_interval_s):
            self._c_suppressed.inc(trigger=trigger)
            return None
        self._last_by_trigger[trigger] = now
        self._dumps += 1
        seq = self._dumps
        try:
            events = [list(e) for e in self._events_fn()[-self.last_n:]]
            doc = {
                "trigger": trigger,
                "detail": detail,
                "seq": seq,
                "wall_time": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
                "mesh": self.mesh,
                "provenance": self._provenance,
                "events": events,
                "metrics": self._metrics_fn(),
                "scheduler": self._state_fn(),
            }
            os.makedirs(self.incident_dir, exist_ok=True)
            path = os.path.join(self.incident_dir,
                                f"incident-{seq:03d}-{trigger}.json")
            with open(path, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True, default=str)
        except Exception:
            return None
        self._c_dumps.inc(trigger=trigger)
        return path


# ---------------------------------------------------------------------------
# Run export
# ---------------------------------------------------------------------------

def export_run(out_dir: str, *, tracer, node_names=None,
               registry: Optional[MetricsRegistry] = None,
               argv: Optional[List[str]] = None) -> Dict[str, str]:
    """Write the full observability artifact set for one serve run:

    ``trace.json`` (graph chrome trace), ``requests.perfetto.json``
    (one track per request), ``timelines.json`` (JSON lifecycle
    records), ``metrics.json`` / ``metrics.prom`` (registry snapshot /
    Prometheus text), ``provenance.json``.  Returns {artifact: path}.
    """
    os.makedirs(out_dir, exist_ok=True)
    paths: Dict[str, str] = {}

    def _p(name: str) -> str:
        paths[name] = os.path.join(out_dir, name)
        return paths[name]

    tracer.export_chrome_trace(_p("trace.json"), node_names or {})
    tl = RequestTimeline.from_tracer(tracer)
    tl.export_perfetto(_p("requests.perfetto.json"))
    tl.to_json(_p("timelines.json"))
    reg = registry if registry is not None else MetricsRegistry()
    with open(_p("metrics.json"), "w") as f:
        f.write(reg.snapshot_json())
    with open(_p("metrics.prom"), "w") as f:
        f.write(reg.to_prometheus())
    with open(_p("provenance.json"), "w") as f:
        json.dump(run_provenance(argv), f, indent=2, sort_keys=True)
    return paths
