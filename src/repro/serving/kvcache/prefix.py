"""Hash-trie prefix index for ref-counted prompt-prefix sharing.

Only *full* prompt blocks are ever shared — they are immutable by
construction (generation writes always land at positions at or beyond
the prompt tail, which lives in an unshared partial block), so sharing
needs no copy-on-write in the steady state; divergence inside a block
simply hashes to a different key and gets its own block.

A block's key is the hash chain ``key_i = H(key_{i-1}, tokens_i)`` over
the token blocks from the start of the prompt — equivalent to a trie
walk over block-sized token chunks, stored flat.  Matching a new prompt
walks the chain until the first miss; every hit is one block of prefill
compute (and storage) saved.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

#: chain key of the empty prefix
ROOT = ("root",)


def chain_key(parent: Tuple, tokens: Sequence[int]) -> Tuple:
    """Key of the block holding ``tokens`` whose prefix has key ``parent``.

    The key IS the nested (parent, tokens) tuple, not its hash: dict
    lookups then fall back to full equality on hash collision, so two
    different prefixes can never silently alias each other's KV blocks.
    Chains are at most max_len/block_size deep — the rehash cost is
    noise next to a prefill."""
    return (parent, tuple(int(t) for t in tokens))


class PrefixIndex:
    """Maps full-prompt-block hash chains to live arena block ids."""

    def __init__(self):
        self._by_key: Dict[Tuple, int] = {}
        self._by_block: Dict[int, Tuple] = {}
        self.stats = {"registered": 0, "hits": 0, "evicted": 0}

    def __len__(self) -> int:
        return len(self._by_key)

    def match(self, prompt: Sequence[int], block_size: int,
              max_blocks: Optional[int] = None) -> Tuple[List[int], Tuple]:
        """Longest chain of already-cached full blocks covering a prompt
        prefix.  Returns (block ids, key of the last matched block).
        ``max_blocks`` caps the walk (the scheduler always leaves at
        least one suffix token to compute, so a fully-cached prompt still
        produces its first-token logits)."""
        hits: List[int] = []
        key = ROOT
        n_full = len(prompt) // block_size
        if max_blocks is not None:
            n_full = min(n_full, max_blocks)
        for i in range(n_full):
            nxt = chain_key(key, prompt[i * block_size:(i + 1) * block_size])
            blk = self._by_key.get(nxt)
            if blk is None:
                break
            hits.append(blk)
            key = nxt
        self.stats["hits"] += len(hits)
        return hits, key

    def register(self, parent: Tuple, tokens: Sequence[int],
                 blk: int) -> Tuple:
        """Publish a freshly-written full block; returns its chain key.
        An existing entry for the same key wins (first writer keeps it —
        identical content, and its ref accounting is already in flight)."""
        key = chain_key(parent, tokens)
        if key not in self._by_key:
            self._by_key[key] = blk
            self._by_block[blk] = key
            self.stats["registered"] += 1
        return key

    def lookup(self, parent: Tuple, tokens: Sequence[int]) -> Optional[int]:
        return self._by_key.get(chain_key(parent, tokens))

    def unregister_block(self, blk: int) -> None:
        """Forget a block (its last reference was freed)."""
        key = self._by_block.pop(blk, None)
        if key is not None:
            del self._by_key[key]
            self.stats["evicted"] += 1
