"""Block-pool allocator for the paged KV cache.

The arena is one preallocated device pytree (per-layer K/V leaves shaped
``[num_blocks, block_size, ...]``); this module is the *host-side*
bookkeeping over it: a free list of fixed-size token blocks, per-block
reference counts (shared prompt-prefix blocks are refcounted, not copied),
and a reservation ledger that makes admission block-availability-aware —
a request is only admitted once its worst-case block demand is reserved,
so decode-time extension can never fail mid-flight (no preemption path is
needed and FlowLimiter back-pressure reflects real memory).

Block 0 is reserved as the *null/trash* block: block tables are padded
with 0, inactive decode rows and padding scatter-writes land there, and
reads from it are always masked.  It is never allocated and never freed.

Invariants (pinned by the hypothesis property tests):

* ``len(free) + blocks_in_use == num_blocks - 1``  (block 0 excluded)
* every allocated block has ``ref >= 1``; free blocks have ``ref == 0``
* ``free`` / ``ref_dec`` on a free block raises (no double free)
* ``reserved <= len(free)`` at all times
"""
from __future__ import annotations

from typing import Dict, List


class BlockPoolError(RuntimeError):
    pass


class BlockPool:
    """Free-list + refcount accounting over ``num_blocks`` fixed blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO reuse keeps recently-touched arena pages hot
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._ref: List[int] = [0] * self.num_blocks
        self._reserved = 0
        self.stats: Dict[str, int] = {
            "allocated": 0, "freed": 0, "cow_copies": 0,
            "peak_in_use": 0,
        }

    # -- capacity -------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Blocks on the free list (including ones already reserved)."""
        return len(self._free)

    @property
    def available_blocks(self) -> int:
        """Blocks that can still be reserved/allocated unreserved."""
        return len(self._free) - self._reserved

    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return self._reserved

    # -- reservations (admission control) -------------------------------
    def can_reserve(self, n: int) -> bool:
        return n <= self.available_blocks

    def reserve(self, n: int) -> None:
        """Set aside ``n`` free blocks for later ``allocate(reserved=True)``
        calls.  Admission reserves a request's worst-case demand up front."""
        if n < 0:
            raise ValueError("negative reservation")
        if not self.can_reserve(n):
            raise BlockPoolError(
                f"cannot reserve {n} blocks "
                f"({self.available_blocks} available)")
        self._reserved += n

    def release_reservation(self, n: int) -> None:
        """Return unused reservation (request finished before its worst
        case, or was cancelled)."""
        if n < 0 or n > self._reserved:
            raise BlockPoolError(
                f"release of {n} exceeds outstanding reservation "
                f"{self._reserved}")
        self._reserved -= n

    # -- alloc / free / share -------------------------------------------
    def allocate(self, *, reserved: bool = False) -> int:
        """Pop a free block (ref becomes 1).  With ``reserved=True`` the
        block is drawn from this caller's earlier :meth:`reserve`."""
        if reserved:
            if self._reserved <= 0:
                raise BlockPoolError("allocate(reserved=True) without "
                                     "an outstanding reservation")
            self._reserved -= 1
        elif self.available_blocks <= 0:
            raise BlockPoolError("block pool exhausted")
        blk = self._free.pop()
        self._ref[blk] = 1
        self.stats["allocated"] += 1
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                        self.blocks_in_use)
        return blk

    def ref_inc(self, blk: int) -> None:
        """Share an allocated block (prefix hit)."""
        self._check_live(blk)
        self._ref[blk] += 1

    def ref_count(self, blk: int) -> int:
        return self._ref[blk]

    def is_shared(self, blk: int) -> bool:
        return self._ref[blk] > 1

    def free(self, blk: int) -> bool:
        """Drop one reference; returns True when the block actually went
        back to the free list (last reference)."""
        self._check_live(blk)
        self._ref[blk] -= 1
        if self._ref[blk] == 0:
            self._free.append(blk)
            self.stats["freed"] += 1
            return True
        return False

    def cow(self, blk: int, *, reserved: bool = False) -> int:
        """Copy-on-write: writing to a shared block forks it.  Returns the
        block to write to — ``blk`` itself when unshared (no copy needed),
        otherwise a fresh block (caller must copy the arena contents and
        drop one ref on ``blk``).  With immutable full-prefix blocks the
        fork path only triggers if a caller ever writes into a shared
        block, but the allocator supports it so schedulers can rely on it.
        """
        self._check_live(blk)
        if self._ref[blk] == 1:
            return blk
        new = self.allocate(reserved=reserved)
        self._ref[blk] -= 1
        self.stats["cow_copies"] += 1
        return new

    # -- internals ------------------------------------------------------
    def _check_live(self, blk: int) -> None:
        if blk <= 0 or blk >= self.num_blocks:
            raise BlockPoolError(f"block id {blk} out of range "
                                 f"(1..{self.num_blocks - 1})")
        if self._ref[blk] <= 0:
            raise BlockPoolError(f"block {blk} is not allocated "
                                 f"(double free / stale reference)")

    def check_invariants(self) -> None:
        """Raise unless the pool is internally consistent (test hook)."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError("duplicate block on free list")
        if 0 in self._free:
            raise AssertionError("trash block 0 on free list")
        for blk in self._free:
            if self._ref[blk] != 0:
                raise AssertionError(f"free block {blk} has ref "
                                     f"{self._ref[blk]}")
        in_use = [b for b in range(1, self.num_blocks) if self._ref[b] > 0]
        if len(in_use) + len(self._free) != self.num_blocks - 1:
            raise AssertionError("free + in-use != num_blocks - 1")
        if not (0 <= self._reserved <= len(self._free)):
            raise AssertionError(
                f"reservation {self._reserved} exceeds free list "
                f"{len(self._free)}")
