"""StateBackend + HybridBackend — recurrent state behind the CacheBackend seam.

Attention's KV cache grows with the sequence; Mamba/xLSTM mixers carry a
**fixed-size recurrent state** (conv tail + ``h`` for Mamba; ``C/n/m``
for mLSTM, ``c/n/h/m`` for sLSTM).  PR 4's CacheBackend protocol was
written against growing caches, so recurrent and hybrid (Jamba-style)
stacks could only be served through the plain slot path with chunked
prefill, speculation and paged admission all gated off.  This module
closes that gap with two backends (docs/STATE_CACHE.md):

* :class:`StateBackend` — a per-slot **state-slab arena**: slot ``i`` of
  every layer's slab is request ``i``'s entire cache.  Capacity is O(1)
  per request regardless of sequence length, so the only admission
  resource is the slot itself and ``grow`` can never fail — the
  concurrent-request capacity story is "as many slots as fit in memory",
  not "as many *tokens*".  Mixed stacks are fine too: attention layers
  keep contiguous slot rows.
* :class:`HybridBackend` — Jamba-style per-layer composition: attention
  layers page K/V through the block-pool arena (block tables, preemptive
  or reserved admission, CachePressure) while recurrent layers live in
  state slabs keyed by the same scheduler slot.  One ``can_admit`` /
  ``CachePressure`` story covers both resource kinds, and ``release``
  frees blocks and clears slab bookkeeping atomically.

What makes every scheduler feature work on O(1) state:

* **Chunked prefill** — the model's recurrent prefill is a sequential
  per-token scan whose update replicates single-token decode op-for-op,
  so the slab row after chunk k is bit-identical to a cold prefill of
  ``prompt[:end_k]``: the slab IS the ingest-frontier checkpoint, and
  chunk boundaries can never shift the state.
* **Speculative verify / truncate** — state has no "rewind the position"
  rollback, so the verify pass leaves slabs *uncommitted* and returns a
  per-position **state stack** (the state after each window token);
  ``truncate(req, new_len)`` commits the accepted prefix's entry via a
  jitted rewind.  ``spec_window`` bounds the stack's memory, surfaced to
  the scheduler through :meth:`CacheBackend.spec_window_cap`.
* **Preemption / cancellation** — ``release`` only drops bookkeeping:
  slab garbage is harmless because the next insert overwrites the whole
  slot row (same argument the slot layout makes for its cache rows).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .backend import CacheBackend, PagedBackend, SlotBackend


class StateBackend(SlotBackend):
    """State-slab arena serving recurrent (and mixed) stacks.

    Inherits the slot layout's allocation story — a slot IS the
    reservation, admission is slot-availability only — because a
    recurrent layer's slot cache already *is* a fixed-size state slab.
    What it adds is the state lifecycle: masked decode commits (the
    engine's ``state`` layout guards mid-ingest frontier state from
    stray batch writes), stack-returning verify, and truncate-as-rewind.

    ``spec_window`` caps the speculative window: verify materializes a
    per-position state stack ([N, 1+k, ...] per state leaf), so the
    draft budget is a memory knob here, not just a latency one.
    """

    kind = "state"
    supports_group_prefill = True

    def __init__(self, engine, num_slots: int = 4, *,
                 spec_window: int = 8):
        super().__init__(engine, num_slots)
        self.spec_window = int(spec_window)
        self._stacks = None                 # last verify's state stacks
        self._stack_pos0: Optional[np.ndarray] = None
        self._held: set = set()             # slots with a live slab

    def _stat_seed(self):
        return {"state_slabs_in_use": 0, "state_slabs_peak": 0}

    # -- capacity / admission -------------------------------------------
    @property
    def slabs_in_use(self) -> int:
        return len(self._held)

    def capacity_desc(self) -> str:
        return (f"engine max_len ({self.engine.max_len}); O(1) state "
                f"slabs impose no per-token bound") + self._mesh_suffix()

    def acquire(self, req, seq) -> None:
        super().acquire(req, seq)
        self._held.add(req.slot)
        self.stats["state_slabs_in_use"] = len(self._held)
        self.stats["state_slabs_peak"] = max(
            self.stats["state_slabs_peak"], len(self._held))
        self._trace("kvcache.state_slabs_in_use", len(self._held))

    def release(self, req) -> None:
        self._held.discard(req.slot)
        self.stats["state_slabs_in_use"] = len(self._held)
        self._trace("kvcache.state_slabs_in_use", len(self._held))
        super().release(req)

    # -- speculative decoding -------------------------------------------
    def spec_window_cap(self, frontier: int) -> int:
        return max(0, min(CacheBackend.spec_window_cap(self, frontier),
                          self.spec_window))

    def verify(self, tokens, positions, active) -> np.ndarray:
        guess, self.cache, self._stacks = self.engine.verify_window(
            self, self.cache, tokens, positions, active)
        self._stack_pos0 = np.asarray(positions).copy()
        return guess

    def truncate(self, req, new_len: int) -> None:
        """Commit the accepted prefix's recurrent state: the stack entry
        for the last *kept* window position (``new_len - 1`` in absolute
        positions, i.e. index ``new_len - pos0 - 1`` into the window)
        becomes the slab row.  Called once per surviving row right after
        its verify tick, while the stacks stashed by :meth:`verify` are
        current — finished rows are evicted instead (slab garbage is
        overwritten by the next insert)."""
        if self._stacks is None:
            return
        idx = int(new_len) - int(self._stack_pos0[req.slot]) - 1
        self.cache = self.engine.state_rewind(self.cache, self._stacks,
                                              req.slot, idx)


class HybridBackend(PagedBackend):
    """Jamba-style per-layer composition: paged attention + state slabs.

    Attention layers inherit the full paged story — block tables,
    watermark/reserve admission, ``CachePressure`` → preemption, tail
    block frees on truncate.  Recurrent layers ride the scheduler slot:
    their slab row needs no admission accounting (it exists for every
    slot) and no ``grow``; ``release`` drops block AND slab bookkeeping
    in one call, so the two resource kinds can never leak apart.

    Prefix sharing is force-disabled: a recurrent state summarizes its
    *entire* prefix positionally, so a shared attention block has no
    state counterpart to share — admission math is pages-only and
    ``prefix_len`` is always 0.
    """

    kind = "hybrid"
    supports_group_prefill = False

    def __init__(self, engine, num_slots: int = 4, *, num_blocks: int,
                 block_size: int = 16, admission: str = "preempt",
                 watermark: int = 0, spec_window: int = 8):
        super().__init__(engine, num_slots, num_blocks=num_blocks,
                         block_size=block_size, prefix_sharing=False,
                         admission=admission, watermark=watermark)
        self.spec_window = int(spec_window)
        self._stacks = None
        self._stack_pos0: Optional[np.ndarray] = None
        self._held: set = set()

    def _stat_seed(self):
        seed = super()._stat_seed()
        seed.update({"state_slabs_in_use": 0, "state_slabs_peak": 0})
        return seed

    # -- capacity / admission -------------------------------------------
    @property
    def slabs_in_use(self) -> int:
        return len(self._held)

    def capacity_desc(self) -> str:
        return (f"hybrid capacity ({self.max_request_tokens()} tokens = "
                f"min of engine max_len {self.engine.max_len} and "
                f"{self.num_blocks - 1} usable blocks x {self.block_size}"
                f" for the attention layers; state slabs are O(1))"
                ) + self._mesh_suffix()

    def acquire(self, req, seq) -> None:
        super().acquire(req, seq)
        self._held.add(req.slot)
        self.stats["state_slabs_in_use"] = len(self._held)
        self.stats["state_slabs_peak"] = max(
            self.stats["state_slabs_peak"], len(self._held))
        self._trace("kvcache.state_slabs_in_use", len(self._held))

    def release(self, req) -> None:
        self._held.discard(req.slot)
        self.stats["state_slabs_in_use"] = len(self._held)
        self._trace("kvcache.state_slabs_in_use", len(self._held))
        super().release(req)

    # -- ingestion refs (see PagedBackend.ingest) ------------------------
    def _insert_ref(self, req, page_ids):
        return (page_ids, req.slot)

    def _extend_ref(self, req, page_ids):
        return (self.tables[req.slot], page_ids, req.slot)

    # -- speculative decoding -------------------------------------------
    def spec_window_cap(self, frontier: int) -> int:
        return max(0, min(CacheBackend.spec_window_cap(self, frontier),
                          self.spec_window))

    def verify(self, tokens, positions, active) -> np.ndarray:
        guess, self.cache, self._stacks = self.engine.verify_window(
            self, self.cache, tokens, positions, active,
            block_tables=self.tables)
        self._stack_pos0 = np.asarray(positions).copy()
        self.stats["blocks_peak"] = self.pool.stats["peak_in_use"]
        self._trace_pool()
        return guess

    def truncate(self, req, new_len: int) -> None:
        """Paged tail frees (super) + recurrent state commit — see
        :meth:`StateBackend.truncate`."""
        super().truncate(req, new_len)
        if self._stacks is None:
            return
        idx = int(new_len) - int(self._stack_pos0[req.slot]) - 1
        self.cache = self.engine.state_rewind(self.cache, self._stacks,
                                              req.slot, idx)
