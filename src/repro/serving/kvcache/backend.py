"""CacheBackend — the one protocol both KV-cache layouts serve.

PR 3 bought paged memory efficiency at the cost of a forked serving
stack: slot and paged each had their own scheduler, engine method pair,
and decode path.  This module collapses the fork.  A
:class:`CacheBackend` owns everything layout-specific about serving one
decode batch:

* **cache allocation** — the device pytree (contiguous slot rows or a
  block-pool arena), built by ``LLMEngine.new_cache(backend)``;
* **row insert** — landing freshly prefilled K/V in the cache
  (whole-row copy vs page scatter);
* **decode dispatch** — one greedy step across the slot batch
  (``cache_pos`` rows vs block tables);
* **extension** — chunked/prefix prefill of a prompt *suffix* against
  already-cached K/V, which is what makes chunked prefill work on both
  layouts (it generalizes PR 3's paged-only ``prefill_extend``);
* **speculative verify / truncate** — scoring a drafted token window in
  one pass and rolling the cache back behind the rejected tail (the
  slot layout rewinds its write position; the paged layout frees
  now-empty tail blocks — docs/SPECULATIVE.md).

The scheduler (:class:`repro.serving.batching.Scheduler`) is backend
agnostic: it talks queueing, slots, chunking and preemption policy; the
backend talks memory.  When the paged backend runs out of blocks it
raises :class:`CachePressure` and the scheduler preempts a victim — the
**preemptive admission** mode (``admission="preempt"``, the default)
that replaces PR 3's worst-case block reservation.  PR 3's semantics
are preserved behind ``admission="reserve"`` for A/B comparison: a
request is admitted only once its worst-case page demand is reserved,
so pressure can never arise mid-flight.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .allocator import BlockPool
from .prefix import ROOT, PrefixIndex


def max_request_tokens(max_len: int, num_blocks: int = 0,
                       block_size: int = 0) -> int:
    """Largest prompt + max_new_tokens a backend can ever serve.  Shared
    with GraphServer so client-side validation matches scheduler-side.
    ``num_blocks`` is the MESH-WIDE arena size: under a serving mesh the
    arena's leaves are sharded across TP ranks, so each block costs
    1/tp of its bytes per rank and the pool is correspondingly larger
    (GraphServer scales its default by ``LLMEngine.cache_shards`` —
    docs/SHARDING.md); the capacity this reports is what the whole mesh
    serves, not one chip."""
    if num_blocks:
        return min(int(max_len), (int(num_blocks) - 1) * int(block_size))
    return int(max_len)


class CachePressure(Exception):
    """Raised by a backend when an allocation cannot be satisfied right
    now.  The scheduler reacts by preempting a victim and retrying —
    this is control flow, not an error."""


class CacheBackend:
    """Base class/protocol: layout-specific serving state + device ops.

    ``bind(stats, trace)`` is called once by the owning scheduler; it
    shares the scheduler's stats dict (one merged view for servers and
    benchmarks) and builds the device cache.
    """

    kind: str = ""
    supports_group_prefill: bool = False

    def __init__(self, engine, num_slots: int = 4):
        self.engine = engine
        self.num_slots = int(num_slots)
        self.cache = None
        self.stats: Dict[str, Any] = {}
        self._trace: Callable[[str, float], None] = lambda name, value: None

    def bind(self, stats: Dict[str, Any],
             trace: Optional[Callable] = None) -> None:
        for k, v in self._stat_seed().items():
            stats.setdefault(k, v)
        self.stats = stats
        if trace is not None:
            self._trace = trace
        self.cache = self.engine.new_cache(self)

    def _stat_seed(self) -> Dict[str, Any]:
        return {}

    # -- capacity / admission -------------------------------------------
    def max_request_tokens(self) -> int:
        """Largest prompt + max_new_tokens this backend serves.  Under a
        serving mesh this is MESH-WIDE capacity (the arena is sharded
        across TP ranks — docs/SHARDING.md), matching the module-level
        :func:`max_request_tokens` contract."""
        raise NotImplementedError

    def capacity_desc(self) -> str:
        raise NotImplementedError

    def mesh_desc(self) -> Dict[str, Any]:
        """Serving-mesh shape this backend's arena is sharded over
        (``{"devices": 1, "axes": {}}`` when unsharded)."""
        return self.engine.mesh_desc

    def _mesh_suffix(self) -> str:
        """Human-readable mesh annotation for capacity descriptions —
        empty when unsharded so single-device error text is unchanged."""
        desc = self.mesh_desc()
        tp = int(desc.get("axes", {}).get("model", 1))
        if tp <= 1:
            return ""
        return (f", mesh-wide over {tp} model-parallel ranks "
                f"({desc.get('devices', tp)} devices)")

    def can_admit(self, req, seq: np.ndarray,
                  chunk: Optional[int]) -> bool:
        """May ``req`` (whose ingest sequence is ``seq``) take a slot now?
        ``chunk`` is the scheduler's chunk size (None = whole prompt)."""
        return True

    def acquire(self, req, seq: np.ndarray) -> None:
        """Take per-request resources at admission (prefix match, block
        refs, reservations).  Sets ``req.prefix_len`` to the tokens
        already covered by shared cache."""
        req.prefix_len = 0

    def release(self, req) -> None:
        """Return every resource ``acquire``/``ingest``/``grow`` took —
        called on eviction AND on preemption."""

    def cancel(self, req) -> None:
        """Abandon ``req`` mid-flight (client disconnect / missed
        deadline): the cancel seam next to ``verify``/``truncate``.

        The base behaviour is exactly :meth:`release` — blocks freed,
        trie refs dropped, reservations returned — because scheduler
        ticks are atomic: a cancel always lands between ticks, when a
        speculative window has already been verified and truncated, so
        there is never half-written state to unwind.  A backend with
        asynchronous device work would override this to also fence or
        abandon in-flight operations for the slot."""
        self.release(req)

    # -- prompt ingestion -----------------------------------------------
    def align_chunk(self, chunk: int) -> int:
        return int(chunk)

    def prefill_group(self, reqs: List) -> np.ndarray:
        """Prefill several equal-length whole prompts as one batch and
        insert each row into its request's slot; returns the first
        generated token per request.  Only meaningful where
        ``supports_group_prefill``."""
        raise NotImplementedError

    def ingest(self, req, seq: np.ndarray, start: int,
               end: int) -> Optional[int]:
        """Compute cache entries for ``seq[start:end)`` of ``req``
        (attending over the already-ingested ``[0, start)``) and write
        them into the cache.  Returns the next token after position
        ``end - 1`` when ``end == len(seq)`` (the request's first
        generated token), else None.  May raise :class:`CachePressure`
        before mutating any state."""
        raise NotImplementedError

    # -- decode ----------------------------------------------------------
    def grow(self, req, pos: int) -> bool:
        """Make sure write position ``pos`` of ``req`` is backed by cache
        memory.  False = out of memory (scheduler should preempt)."""
        return True

    def decode(self, last_tokens: np.ndarray, positions: np.ndarray,
               active: np.ndarray) -> np.ndarray:
        """One greedy decode step across all slots; returns [N] tokens."""
        raise NotImplementedError

    # -- speculative decoding (verify / truncate seam) --------------------
    def spec_window_cap(self, frontier: int) -> int:
        """Largest draft count ``k`` a verify tick may use when the
        batch's most-advanced row sits at ``frontier``.  The base bound
        is cache geometry — the window writes at every row's frontier,
        so ``frontier + k`` must stay inside ``max_len``.  State-slab
        backends clamp further: their verify materializes a per-position
        state stack, so the window is also a memory budget
        (``spec_window``, docs/STATE_CACHE.md)."""
        return self.engine.max_len - 1 - int(frontier)

    def verify(self, tokens: np.ndarray, positions: np.ndarray,
               active: np.ndarray) -> np.ndarray:
        """Score a speculative window — ``tokens`` is [N, 1+k] (each
        row's last emitted token ++ k drafted tokens) — in one batched
        forward pass; returns the [N, 1+k] greedy argmax at every window
        position.  K/V for the whole window is written at
        ``positions[slot]..positions[slot]+k``; the scheduler then keeps
        the accepted prefix (rewinding ``positions``) and calls
        :meth:`truncate` so the backend can reclaim memory behind the
        rejected tail."""
        raise NotImplementedError

    def truncate(self, req, new_len: int) -> None:
        """Roll ``req``'s cache memory back to ``new_len`` tokens after
        speculative verification rejected drafted tail tokens.

        The slot layout needs no action: the scheduler's rewound
        ``positions[slot]`` masks the stale tail K/V, and the next
        verify/decode window overwrites it before it can ever become
        readable.  The paged layout overrides this to free now-empty
        tail blocks back to the :class:`BlockPool`."""


class SlotBackend(CacheBackend):
    """Contiguous layout: one max_len cache row per slot.

    No per-request memory bookkeeping — a slot IS the reservation — so
    admission is slot-availability only and ``grow`` never fails.
    Chunked prefill extends a slot row in place (suffix K/V written at
    the row's current offset)."""

    kind = "slot"
    supports_group_prefill = True

    def max_request_tokens(self) -> int:
        return self.engine.max_len

    def capacity_desc(self) -> str:
        return f"engine max_len ({self.engine.max_len})" \
            + self._mesh_suffix()

    def prefill_group(self, reqs: List) -> np.ndarray:
        """The batch is padded to a power-of-two width with duplicates of
        its first row: group width depends on arrival timing, so without
        bucketing each new width is a fresh XLA compile at an
        unpredictable moment.  Padding rows are row-independent (they
        cannot perturb real rows) and are simply not inserted."""
        width = 1
        while width < len(reqs):
            width *= 2
        prompts = np.stack([r.prompt for r in reqs]
                           + [reqs[0].prompt] * (width - len(reqs)))
        first, rows = self.engine.prefill(prompts)
        for i, req in enumerate(reqs):
            self.cache = self.engine.insert(self, self.cache, rows, i,
                                            req.slot)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_padded_rows"] += width - len(reqs)
        self.stats["prefill_tokens"] += int(prompts.shape[1]) * len(reqs)
        return first

    def ingest(self, req, seq, start, end) -> Optional[int]:
        if start == 0:
            first, rows = self.engine.prefill(seq[None, :end])
            self.cache = self.engine.insert(self, self.cache, rows, 0,
                                            req.slot)
            tok = int(first[0])
        else:
            first, self.cache = self.engine.extend(
                self, self.cache, seq[start:end], start, req.slot)
            tok = int(first[0])
            self.stats["extend_prefills"] += 1
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += int(end - start)
        return tok if end == len(seq) else None

    def decode(self, last_tokens, positions, active) -> np.ndarray:
        next_tok, self.cache = self.engine.decode(
            self, self.cache, last_tokens, positions, active)
        return next_tok

    def verify(self, tokens, positions, active) -> np.ndarray:
        guess, self.cache = self.engine.verify(
            self, self.cache, tokens, positions, active)
        return guess


class PagedBackend(CacheBackend):
    """Paged layout: K/V in a block-pool arena, reached via per-slot
    block tables; full prompt blocks shared through a hash-trie prefix
    index (ref-counted; a hit skips that prefix's prefill compute).

    Admission modes:

    * ``"preempt"`` (default) — optimistic watermark admission: a
      request is admitted once the blocks for its *next chunk* (plus
      ``watermark`` spare blocks) are free.  On pool exhaustion the
      backend raises :class:`CachePressure` / returns False from
      :meth:`grow` and the scheduler preempts the least-important
      request, whose blocks are freed and whose cache is recomputed on
      readmission — deterministic greedy decode keeps every output
      bit-identical.
    * ``"reserve"`` — PR 3's worst-case reservation: admission reserves
      ``ceil((prompt + max_new) / block_size)`` pages up front, so
      extension can never fail mid-flight (and preemption never
      triggers).  Kept for A/B comparison; it strands blocks that the
      typical request never touches.
    """

    kind = "paged"
    supports_group_prefill = False

    def __init__(self, engine, num_slots: int = 4, *, num_blocks: int,
                 block_size: int = 16, prefix_sharing: bool = True,
                 admission: str = "preempt", watermark: int = 0):
        super().__init__(engine, num_slots)
        if admission not in ("preempt", "reserve"):
            raise ValueError(f"admission must be 'preempt' or 'reserve', "
                             f"got {admission!r}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.admission = admission
        self.watermark = int(watermark)
        self.pool = BlockPool(self.num_blocks, self.block_size)
        self.prefix: Optional[PrefixIndex] = \
            PrefixIndex() if prefix_sharing else None
        self.pages_per_seq = engine.max_len // self.block_size
        self.tables = np.zeros((self.num_slots, self.pages_per_seq),
                               np.int32)

    def _stat_seed(self):
        return {
            "prefill_tokens_saved": 0,    # covered by shared prefix blocks
            "shared_block_hits": 0,
            "admission_blocked_on_blocks": 0, "blocks_peak": 0,
        }

    # -- capacity / admission -------------------------------------------
    def max_request_tokens(self) -> int:
        return max_request_tokens(self.engine.max_len, self.num_blocks,
                                  self.block_size)

    def capacity_desc(self) -> str:
        return (f"paged-arena capacity ({self.max_request_tokens()} tokens"
                f" = min of engine max_len {self.engine.max_len} and "
                f"{self.num_blocks - 1} usable blocks x "
                f"{self.block_size})") + self._mesh_suffix()

    def _worst_case_pages(self, req) -> int:
        return -(-(req.prompt.size + req.max_new_tokens)
                 // self.block_size)

    def _match(self, seq):
        if self.prefix is None:
            return [], ROOT
        return self.prefix.match(seq, self.block_size,
                                 max_blocks=(len(seq) - 1)
                                 // self.block_size)

    def can_admit(self, req, seq, chunk) -> bool:
        hits, parent = self._match(seq)
        # stash for acquire(): nothing can change the trie between the
        # admission check and the acquire that immediately follows it
        self._admit_match = (req, hits, parent)
        if self.admission == "reserve":
            need = max(0, self._worst_case_pages(req) - len(hits))
            ok = self.pool.can_reserve(need)
        else:
            # optimistic: only the next chunk's pages (beyond shared
            # prefix hits) plus the watermark must be free right now.
            # The target is capped at the arena size — a near-capacity
            # request that passed submit validation must stay admissible
            # once the pool fully drains, or it would starve the queue
            # forever (the watermark is a damper, not a capacity cut).
            start = len(hits) * self.block_size
            end = len(seq) if chunk is None else min(len(seq),
                                                     start + chunk)
            need = -(-end // self.block_size) - len(hits)
            target = min(need + self.watermark, self.num_blocks - 1)
            ok = self.pool.available_blocks >= target
        if not ok:
            self.stats["admission_blocked_on_blocks"] += 1
        return ok

    def acquire(self, req, seq) -> None:
        stash = getattr(self, "_admit_match", None)
        if stash is not None and stash[0] is req:
            _, hits, parent = stash
            self._admit_match = None
        else:
            hits, parent = self._match(seq)
        for b in hits:
            self.pool.ref_inc(b)
        self.tables[req.slot] = 0
        self.tables[req.slot, :len(hits)] = hits
        req.blocks = list(hits)
        req.n_pages = len(hits)
        req.registered = len(hits)
        req.prefix_key = parent
        req.prefix_len = len(hits) * self.block_size
        if hits:
            self.stats["shared_block_hits"] += len(hits)
            self.stats["prefill_tokens_saved"] += req.prefix_len
        if self.admission == "reserve":
            need = max(0, self._worst_case_pages(req) - len(hits))
            self.pool.reserve(need)
            req.reserved_left = need
        self._trace_pool()

    def release(self, req) -> None:
        if req.slot >= 0:
            self.tables[req.slot] = 0
        for b in req.blocks:
            if self.pool.free(b) and self.prefix is not None:
                self.prefix.unregister_block(b)
        req.blocks = []
        req.n_pages = 0
        req.registered = 0
        req.prefix_len = 0
        req.prefix_key = None
        if req.reserved_left:
            self.pool.release_reservation(req.reserved_left)
            req.reserved_left = 0
        self._trace_pool()

    # -- allocation helpers ---------------------------------------------
    def _can_alloc(self, n: int) -> bool:
        if self.admission == "reserve":
            return True                   # drawn from the reservation
        return self.pool.available_blocks >= n

    def _alloc(self, req) -> int:
        if self.admission == "reserve":
            req.reserved_left -= 1
            blk = self.pool.allocate(reserved=True)
        else:
            blk = self.pool.allocate()
        self.stats["blocks_peak"] = self.pool.stats["peak_in_use"]
        return blk

    # -- ingestion -------------------------------------------------------
    def align_chunk(self, chunk: int) -> int:
        bs = self.block_size
        return max(bs, -(-int(chunk) // bs) * bs)

    def _insert_ref(self, req, page_ids):
        """Engine write ref for a whole-prompt insert (hybrid adds the
        slot so recurrent slabs land alongside the page scatter)."""
        return page_ids

    def _extend_ref(self, req, page_ids):
        """Engine write ref for a chunked/prefix extend."""
        return (self.tables[req.slot], page_ids)

    def ingest(self, req, seq, start, end) -> Optional[int]:
        bs = self.block_size
        new_pages = -(-end // bs) - req.n_pages
        if not self._can_alloc(new_pages):
            raise CachePressure(f"{new_pages} blocks needed, "
                                f"{self.pool.available_blocks} free")
        owned = [self._alloc(req) for _ in range(new_pages)]
        self.tables[req.slot, req.n_pages:req.n_pages + new_pages] = owned
        req.blocks += owned
        req.n_pages += new_pages
        page_ids = np.zeros(self.pages_per_seq, np.int32)
        page_ids[:new_pages] = owned
        if start == 0:
            first, rows = self.engine.prefill(seq[None, :end])
            self.cache = self.engine.insert(self, self.cache, rows, 0,
                                            self._insert_ref(req, page_ids))
        else:
            first, self.cache = self.engine.extend(
                self, self.cache, seq[start:end], start,
                self._extend_ref(req, page_ids))
            self.stats["extend_prefills"] += 1
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += int(end - start)
        if self.prefix is not None:
            # newly-written FULL blocks become shareable (immutable from
            # here on: later writes always land at positions >= end)
            for i in range(req.registered, end // bs):
                req.prefix_key = self.prefix.register(
                    req.prefix_key, seq[i * bs:(i + 1) * bs],
                    req.blocks[i])
                req.registered = i + 1
        self._trace_pool()
        return int(first[0]) if end == len(seq) else None

    # -- decode ----------------------------------------------------------
    def grow(self, req, pos: int) -> bool:
        page = pos // self.block_size
        if page < req.n_pages:
            return True
        if not self._can_alloc(1):
            return False
        blk = self._alloc(req)
        req.blocks.append(blk)
        self.tables[req.slot, page] = blk
        req.n_pages += 1
        return True

    def decode(self, last_tokens, positions, active) -> np.ndarray:
        next_tok, self.cache = self.engine.decode(
            self, self.cache, last_tokens, positions, active,
            block_tables=self.tables)
        self.stats["blocks_peak"] = self.pool.stats["peak_in_use"]
        self._trace_pool()
        return next_tok

    def verify(self, tokens, positions, active) -> np.ndarray:
        guess, self.cache = self.engine.verify(
            self, self.cache, tokens, positions, active,
            block_tables=self.tables)
        self.stats["blocks_peak"] = self.pool.stats["peak_in_use"]
        self._trace_pool()
        return guess

    def truncate(self, req, new_len: int) -> None:
        """Trim ``req``'s block table to ``ceil(new_len / block_size)``
        pages, freeing tail blocks that held only rejected draft tokens.

        Safe by construction w.r.t. sharing: the verify window starts at
        or past the request's generation frontier, which always lies
        beyond its shared/registered prefix blocks — so a freed tail
        block has ref 1 and is unregistered (the prefix-index unregister
        mirrors :meth:`release` for defense in depth).  In
        ``admission="reserve"`` mode each freed page returns to the
        request's reservation, preserving the never-fail-mid-flight
        guarantee."""
        keep = -(-int(new_len) // self.block_size)
        if keep < req.registered:
            raise RuntimeError(
                f"request {req.id!r}: truncate to {new_len} tokens would "
                f"drop registered prefix blocks ({req.registered} pages)")
        while req.n_pages > keep:
            blk = req.blocks.pop()
            req.n_pages -= 1
            self.tables[req.slot, req.n_pages] = 0
            if self.pool.free(blk) and self.prefix is not None:
                self.prefix.unregister_block(blk)
            if self.admission == "reserve":
                self.pool.reserve(1)
                req.reserved_left += 1
        self._trace_pool()

    def _trace_pool(self) -> None:
        self._trace("kvcache.blocks_in_use", self.pool.blocks_in_use)
        self._trace("kvcache.blocks_free", self.pool.free_blocks)


def make_backend(engine, *, paged: bool = False, num_slots: int = 4,
                 num_blocks: int = 0, block_size: int = 16,
                 prefix_sharing: bool = True, admission: str = "preempt",
                 watermark: int = 0, backend: Optional[str] = None,
                 spec_window: int = 8) -> CacheBackend:
    """Backend factory used by the serving calculator and launchers.

    ``backend`` selects the layout by name — ``"slot" | "paged" |
    "state" | "hybrid"`` — and wins over the legacy ``paged`` flag
    (kept so existing call sites stay valid).  ``spec_window`` is the
    state/hybrid verify-window cap (docs/STATE_CACHE.md)."""
    kind = backend if backend is not None else \
        ("paged" if paged else "slot")
    if kind == "slot":
        return SlotBackend(engine, num_slots)
    if kind == "paged":
        return PagedBackend(engine, num_slots, num_blocks=num_blocks,
                            block_size=block_size,
                            prefix_sharing=prefix_sharing,
                            admission=admission, watermark=watermark)
    # deferred import: state.py subclasses the classes defined above
    from .state import HybridBackend, StateBackend
    if kind == "state":
        return StateBackend(engine, num_slots, spec_window=spec_window)
    if kind == "hybrid":
        return HybridBackend(engine, num_slots, num_blocks=num_blocks,
                             block_size=block_size, admission=admission,
                             watermark=watermark,
                             spec_window=spec_window)
    raise ValueError(f"unknown backend kind {kind!r} (expected 'slot', "
                     f"'paged', 'state' or 'hybrid')")
