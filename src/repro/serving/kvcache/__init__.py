"""KV-cache subsystem: the CacheBackend protocol (contiguous slot rows,
paged block-pool arena, O(1) state slabs, or the per-layer hybrid mix —
behind one interface: allocation, insert, decode, extend, speculative
verify/truncate), the block-pool allocator, and ref-counted
prompt-prefix sharing (see docs/KV_CACHE.md + docs/STATE_CACHE.md +
docs/SCHEDULER.md + docs/SPECULATIVE.md)."""
from .allocator import BlockPool, BlockPoolError
from .backend import (CacheBackend, CachePressure, PagedBackend,
                      SlotBackend, make_backend, max_request_tokens)
from .prefix import PrefixIndex, ROOT, chain_key
from .state import HybridBackend, StateBackend

__all__ = ["BlockPool", "BlockPoolError", "CacheBackend", "CachePressure",
           "HybridBackend", "PagedBackend", "PrefixIndex", "ROOT",
           "SlotBackend", "StateBackend", "chain_key", "make_backend",
           "max_request_tokens"]
