"""Paged KV-cache subsystem: block-pool allocator over one preallocated
arena, ref-counted prompt-prefix sharing, and the host bookkeeping behind
the paged decode path (see docs/KV_CACHE.md)."""
from .allocator import BlockPool, BlockPoolError
from .prefix import PrefixIndex, ROOT, chain_key

__all__ = ["BlockPool", "BlockPoolError", "PrefixIndex", "ROOT",
           "chain_key"]
