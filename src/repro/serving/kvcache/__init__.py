"""KV-cache subsystem: the CacheBackend protocol (contiguous slot rows
vs paged block-pool arena behind one interface — allocation, insert,
decode, extend, speculative verify/truncate), the block-pool
allocator, and ref-counted prompt-prefix sharing (see
docs/KV_CACHE.md + docs/SCHEDULER.md + docs/SPECULATIVE.md)."""
from .allocator import BlockPool, BlockPoolError
from .backend import (CacheBackend, CachePressure, PagedBackend,
                      SlotBackend, make_backend, max_request_tokens)
from .prefix import PrefixIndex, ROOT, chain_key

__all__ = ["BlockPool", "BlockPoolError", "CacheBackend", "CachePressure",
           "PagedBackend", "PrefixIndex", "ROOT", "SlotBackend",
           "chain_key", "make_backend", "max_request_tokens"]
