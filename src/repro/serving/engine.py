"""LLM inference engine: jitted prefill + decode with KV/SSM cache.

The engine is the "model inference" component consumed by the MediaPipe
graph's InferenceCalculator (paper §6.1 'performs ML inference ... using an
inference engine').  On a pod it holds pjit-sharded params; in the examples
and tests it runs a reduced config on CPU.

Two decode modes:

* :meth:`generate` — classic static batch: prefill a [B, S] batch, then
  greedy-decode all rows in lockstep (scalar ``cache_pos``).
* the slot API (:meth:`new_slot_cache` / :meth:`insert_slot` /
  :meth:`decode_slots`) — continuous batching: the decode batch is a fixed
  set of slots, each an independent request at its own position, and
  requests are inserted/evicted while the batch keeps decoding.  Used by
  :class:`repro.serving.batching.SlotScheduler` and the GraphServer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig
from ..models.model import Model
from ..models.transformer import (DEFAULT_FLAGS, RuntimeFlags,
                                  check_paged_support)
from ..runtime.steps import (make_decode_step, make_paged_decode_step,
                             make_prefill_extend_step, make_prefill_step,
                             make_slot_decode_step)
from .batching import make_paged_insert, make_slot_insert


class LLMEngine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 max_len: int = 512, seed: int = 0,
                 flags: RuntimeFlags = DEFAULT_FLAGS):
        self.cfg = cfg
        self.model = Model(cfg)
        self.max_len = max_len
        self.flags = flags
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = params
        self._prefill = jax.jit(make_prefill_step(self.model, max_len,
                                                  flags))
        self._decode = jax.jit(make_decode_step(self.model, flags))
        self._slot_decode = jax.jit(make_slot_decode_step(self.model, flags))
        self._insert = jax.jit(make_slot_insert())
        # paged-path jits, built lazily on first use (one per block_size /
        # prefix_len — see the paged API section below)
        self._paged_decode = None
        self._paged_insert = None
        self._paged_block_size = 0
        self._extend_steps: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    # static-batch generation
    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, max_new_tokens: int = 16,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """Greedy-decode a batch. tokens: [B, S] int32 -> [B, max_new]."""
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        batch = {"tokens": tokens}
        next_tok, cache = self._prefill(self.params, batch)
        out = [np.asarray(next_tok)]
        cur = next_tok[:, None]
        pos = S
        for _ in range(max_new_tokens - 1):
            cur, cache = self._decode(self.params, cur, cache,
                                      jnp.asarray(pos, jnp.int32))
            out.append(np.asarray(cur[:, 0]))
            pos += 1
            if eos_id is not None and bool((cur == eos_id).all()):
                break
        return np.stack(out, axis=1)

    def __call__(self, payload):
        """Engine interface for InferenceCalculator: payload is a dict
        {'tokens': [B,S] int32, 'max_new_tokens': int}."""
        return self.generate(payload["tokens"],
                             payload.get("max_new_tokens", 16))

    # ------------------------------------------------------------------
    # slot API (continuous batching)
    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> Tuple[np.ndarray, Dict]:
        """Prefill [B, S] prompts; returns (first tokens [B], cache rows).
        All rows must share one length — the SlotScheduler groups by length
        so padding never perturbs positions (exactness over utilisation)."""
        next_tok, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens, jnp.int32)})
        return np.asarray(next_tok), cache

    def new_slot_cache(self, num_slots: int):
        """Zeroed decode cache with a batch width of ``num_slots``."""
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.model.abstract_cache(num_slots, self.max_len))

    def insert_slot(self, cache, rows, row: int, slot: int):
        """Copy prefilled cache row ``row`` of ``rows`` into ``slot``."""
        return self._insert(cache, rows, jnp.asarray(row, jnp.int32),
                            jnp.asarray(slot, jnp.int32))

    def decode_slots(self, cache, last_tokens: np.ndarray,
                     positions: np.ndarray, active: np.ndarray
                     ) -> Tuple[np.ndarray, Dict]:
        """One greedy decode step across all slots.

        last_tokens/positions/active: [N] — each slot's most recent token,
        cache offset, and occupancy.  Returns ([N] next tokens, cache);
        inactive slots yield the pad token."""
        next_tok, cache = self._slot_decode(
            self.params,
            jnp.asarray(last_tokens, jnp.int32)[:, None],
            cache,
            jnp.asarray(positions, jnp.int32),
            jnp.asarray(active, bool))
        return np.asarray(next_tok[:, 0]), cache

    # ------------------------------------------------------------------
    # paged API (block-pool KV cache; see repro.serving.kvcache)
    # ------------------------------------------------------------------
    def new_paged_cache(self, num_blocks: int, block_size: int):
        """Zeroed paged arena of ``num_blocks`` blocks of ``block_size``
        tokens (block 0 is the trash block).  Also builds the paged
        decode/insert jits for this ``block_size``."""
        check_paged_support(self.cfg)
        if self.max_len % block_size != 0:
            raise ValueError(f"engine max_len {self.max_len} must be a "
                             f"multiple of block_size {block_size}")
        if self.flags.use_flash:
            raise ValueError("paged serving requires attn_impl "
                             "'chunked'|'naive' (the prefix-extend "
                             "prefill has no flash path yet)")
        if getattr(self.flags, "model_size", 1) > 1:
            raise ValueError("paged serving is single-host for now "
                             "(prefix-extend attention is not "
                             "sequence-parallel)")
        if self.cfg.use_mla and getattr(self.flags, "use_paged_kernel",
                                        False):
            raise ValueError("use_paged_kernel covers GQA/MHA/MQA only; "
                             "MLA paged decode uses the latent-gather "
                             "path (drop the flag)")
        if self._paged_decode is None or \
                self._paged_block_size != int(block_size):
            # jits are cached per block_size (shapes retrace on their own)
            self._paged_block_size = int(block_size)
            self._paged_decode = jax.jit(
                make_paged_decode_step(self.model, self.flags))
            self._paged_insert = jax.jit(make_paged_insert(block_size))
            self._extend_steps.clear()
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.model.abstract_paged_cache(num_blocks, block_size))

    def paged_insert(self, cache, rows, row: int, page_ids: np.ndarray):
        """Scatter prefilled cache row ``row`` of ``rows`` into the arena
        at ``page_ids`` ([max_len // block_size] int32, 0 = skip page)."""
        return self._paged_insert(cache, rows, jnp.asarray(row, jnp.int32),
                                  jnp.asarray(page_ids, jnp.int32))

    def decode_paged(self, cache, last_tokens: np.ndarray,
                     positions: np.ndarray, active: np.ndarray,
                     block_tables: np.ndarray) -> Tuple[np.ndarray, Dict]:
        """One greedy decode step across all slots, K/V through block
        tables ([N, P] int32; inactive rows all-zero)."""
        next_tok, cache = self._paged_decode(
            self.params,
            jnp.asarray(last_tokens, jnp.int32)[:, None],
            cache,
            jnp.asarray(positions, jnp.int32),
            jnp.asarray(active, bool),
            jnp.asarray(block_tables, jnp.int32))
        return np.asarray(next_tok[:, 0]), cache

    def prefill_extend(self, suffix_tokens: np.ndarray,
                       cache, table_row: np.ndarray,
                       prefix_len: int) -> Tuple[np.ndarray, Dict]:
        """Prefill one prompt's suffix against its shared prefix blocks.

        suffix_tokens: [S'] — prompt tokens from ``prefix_len`` on;
        table_row: [P] int32 block table covering the prefix pages.
        Returns (first generated token [1], suffix cache rows [1, ...] to
        :meth:`paged_insert`).  Compiled per (prefix_len, S') shape."""
        step = self._extend_steps.get(prefix_len)
        if step is None:
            step = jax.jit(make_prefill_extend_step(
                self.model, prefix_len, self._paged_block_size,
                self.max_len, self.flags))
            self._extend_steps[prefix_len] = step
        next_tok, rows = step(
            self.params,
            jnp.asarray(suffix_tokens, jnp.int32)[None],
            cache,
            jnp.asarray(table_row, jnp.int32)[None])
        return np.asarray(next_tok), rows
