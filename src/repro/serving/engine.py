"""LLM inference engine: jitted prefill + decode with KV/SSM cache.

The engine is the "model inference" component consumed by the MediaPipe
graph's InferenceCalculator (paper §6.1 'performs ML inference ... using an
inference engine').  On a pod it holds pjit-sharded params; in the examples
and tests it runs a reduced config on CPU.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig
from ..models.model import Model
from ..models.transformer import DEFAULT_FLAGS, RuntimeFlags
from ..runtime.steps import make_decode_step, make_prefill_step


class LLMEngine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 max_len: int = 512, seed: int = 0,
                 flags: RuntimeFlags = DEFAULT_FLAGS):
        self.cfg = cfg
        self.model = Model(cfg)
        self.max_len = max_len
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = params
        self._prefill = jax.jit(make_prefill_step(self.model, max_len,
                                                  flags))
        self._decode = jax.jit(make_decode_step(self.model, flags))

    def generate(self, tokens: np.ndarray, max_new_tokens: int = 16,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """Greedy-decode a batch. tokens: [B, S] int32 -> [B, max_new]."""
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        batch = {"tokens": tokens}
        next_tok, cache = self._prefill(self.params, batch)
        out = [np.asarray(next_tok)]
        cur = next_tok[:, None]
        pos = S
        for _ in range(max_new_tokens - 1):
            cur, cache = self._decode(self.params, cur, cache,
                                      jnp.asarray(pos, jnp.int32))
            out.append(np.asarray(cur[:, 0]))
            pos += 1
            if eos_id is not None and bool((cur == eos_id).all()):
                break
        return np.stack(out, axis=1)

    def __call__(self, payload):
        """Engine interface for InferenceCalculator: payload is a dict
        {'tokens': [B,S] int32, 'max_new_tokens': int}."""
        return self.generate(payload["tokens"],
                             payload.get("max_new_tokens", 16))
