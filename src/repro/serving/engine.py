"""LLM inference engine: jitted prefill + decode with KV/SSM cache.

The engine is the "model inference" component consumed by the MediaPipe
graph's InferenceCalculator (paper §6.1 'performs ML inference ... using an
inference engine').  On a pod it holds pjit-sharded params; in the examples
and tests it runs a reduced config on CPU.

Two decode modes:

* :meth:`generate` — classic static batch: prefill a [B, S] batch, then
  greedy-decode all rows in lockstep (scalar ``cache_pos``).
* the serving API — continuous batching over a
  :class:`~repro.serving.kvcache.CacheBackend`:
  :meth:`new_cache` / :meth:`insert` / :meth:`decode` / :meth:`extend`
  / :meth:`verify` dispatched on the backend's cache layout (contiguous
  slot rows or a paged block-pool arena).  Jitted steps are cached per
  layout, so one engine can serve slot and paged backends at the same
  time.  Used by :class:`repro.serving.batching.Scheduler` and the
  GraphServer.  ``verify`` is the speculative-decoding scoring pass
  (docs/SPECULATIVE.md).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tracer as trace_mod
from ..core.metrics import MetricsRegistry, NullRegistry
from ..models.config import ArchConfig
from ..models.model import Model
from ..models.transformer import (DEFAULT_FLAGS, RuntimeFlags,
                                  check_hybrid_support,
                                  check_mixed_extend_support,
                                  check_paged_support)
from ..runtime.steps import (kernel_path, make_decode_step, make_extend_step,
                             make_hybrid_insert, make_paged_insert,
                             make_prefill_step, make_serve_decode_step,
                             make_slot_insert, make_state_extend_step,
                             make_state_rewind, make_state_verify_step,
                             make_verify_step)

#: cache layouts whose recurrent layers live in O(1) state slabs — decode
#: masks state commits per row, and verify returns per-position state
#: stacks for rewind (docs/STATE_CACHE.md)
STATE_KINDS = ("state", "hybrid")


class LLMEngine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 max_len: int = 512, seed: int = 0,
                 flags: RuntimeFlags = DEFAULT_FLAGS,
                 mesh=None):
        self.cfg = cfg
        self.model = Model(cfg)
        self.max_len = max_len
        # Tensor-parallel serving (docs/SHARDING.md): with a device mesh
        # the params are placed per sharding/rules.py::param_specs and
        # every backend's cache arena is allocated with
        # sharding/rules.py::cache_specs shardings (new_cache); jitted
        # serving steps then run SPMD-partitioned — GSPMD for the gather
        # paths, shard_map for the fused flash-decode kernel
        # (flags.decode_mesh).  Greedy tokens stay bit-identical to the
        # unsharded engine: head/expert parallelism never reorders any
        # per-token reduction.
        self.mesh = mesh
        self.tp = int(mesh.shape.get("model", 1)) if mesh is not None else 1
        if self.tp > 1:
            import dataclasses
            flags = dataclasses.replace(flags, decode_shards=self.tp,
                                        decode_mesh=mesh)
        self.flags = flags
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        if mesh is not None:
            from ..sharding.rules import param_specs
            params = jax.device_put(
                params, param_specs(self.model.template, mesh))
        self.params = params
        # Engine-side profiling registry (docs/OBSERVABILITY.md): jit
        # compile counts + compile wall time per (step, layout, width)
        # cache entry.  GraphServer.metrics() merges it with the
        # scheduler's registry.  Under tracer.COMPILED_OUT the registry
        # is a no-op sink.
        self.metrics: MetricsRegistry = \
            NullRegistry() if trace_mod.COMPILED_OUT else MetricsRegistry()
        self._prefill = self._timed(
            jax.jit(make_prefill_step(self.model, max_len, flags)),
            "prefill", "batch")
        self._decode = self._timed(
            jax.jit(make_decode_step(self.model, flags)),
            "decode", "batch")
        # serving jits, built lazily per cache layout: key is
        # (backend.kind, block_size); extend steps add prefix_len,
        # verify steps add the window width 1+k
        self._serve: Dict[Tuple, Dict[str, Any]] = {}
        self._extend_steps: Dict[Tuple, Any] = {}
        self._verify_steps: Dict[Tuple, Any] = {}
        self._state_rewind = None       # built on first verify/truncate
        # per-(step, layout) cache of kernel-path metric handles +
        # resolved label sets (_observe_kernel runs on every decode
        # tick; keep it off the registry lookup path)
        self._kernel_obs: Dict[Tuple, Tuple] = {}

    def _timed(self, fn, step: str, layout: str, width: str = ""):
        """Wrap a jitted step: the first call (= trace + compile + run)
        is timed to a ``jax.block_until_ready`` barrier and recorded as
        one jit-cache compile; later calls pay one Python-level
        indirection and nothing else."""
        state = {"first": True}

        def wrapped(*args, **kw):
            if state["first"]:
                state["first"] = False
                t0 = time.perf_counter()
                out = fn(*args, **kw)
                jax.block_until_ready(out)
                dt_ms = (time.perf_counter() - t0) * 1e3
                self.metrics.counter(
                    "engine.jit_compiles",
                    "jitted serving steps compiled, by cache key").inc(
                        step=step, layout=layout, width=width)
                self.metrics.histogram(
                    "engine.jit_compile_ms",
                    "first-call wall time per jit cache entry "
                    "(trace + compile + run)").observe(
                        dt_ms, step=step, layout=layout, width=width)
                return out
            return fn(*args, **kw)

        return wrapped

    @staticmethod
    def _layout(backend) -> str:
        return f"{backend.kind}/{getattr(backend, 'block_size', 0)}"

    def _observe_kernel(self, step: str, backend, t0: float) -> None:
        """Record which attention implementation served a decode/verify
        step (``fused`` Pallas flash-decode vs the gather ``fallback``)
        and its wall time — so a silent fall-off the fast path shows up
        in ``metrics_text()``, not just as degraded throughput.  The
        timer spans the host-side token conversion, i.e. includes the
        device sync.  Runs on every decode tick: the dispatch decision,
        label set, and metric handles are resolved once per
        (step, layout) and cached."""
        if not self.metrics.enabled:
            return
        dt_ms = (time.perf_counter() - t0) * 1e3
        key = (step, backend.kind, getattr(backend, "block_size", 0))
        ent = self._kernel_obs.get(key)
        if ent is None:
            labels = {"path": kernel_path(self.cfg, self.flags,
                                          backend.kind),
                      "step": step, "layout": self._layout(backend)}
            ent = (self.metrics.counter(
                       "engine.kernel_path",
                       "decode/verify steps by attention implementation "
                       "(fused flash-decode kernel vs gather fallback)"
                   ).bind(**labels),
                   self.metrics.histogram(
                       "engine.kernel_ms",
                       "wall time per decode/verify step, by kernel "
                       "path").bind(**labels))
            self._kernel_obs[key] = ent
        ctr, hist = ent
        ctr.inc()
        hist.observe(dt_ms)

    # ------------------------------------------------------------------
    # static-batch generation
    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, max_new_tokens: int = 16,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """Greedy-decode a batch. tokens: [B, S] int32 -> [B, max_new]."""
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        batch = {"tokens": tokens}
        next_tok, cache = self._prefill(self.params, batch)
        out = [np.asarray(next_tok)]
        cur = next_tok[:, None]
        pos = S
        for _ in range(max_new_tokens - 1):
            cur, cache = self._decode(self.params, cur, cache,
                                      jnp.asarray(pos, jnp.int32))
            out.append(np.asarray(cur[:, 0]))
            pos += 1
            if eos_id is not None and bool((cur == eos_id).all()):
                break
        return np.stack(out, axis=1)

    def __call__(self, payload):
        """Engine interface for InferenceCalculator: payload is a dict
        {'tokens': [B,S] int32, 'max_new_tokens': int}."""
        return self.generate(payload["tokens"],
                             payload.get("max_new_tokens", 16))

    # ------------------------------------------------------------------
    # serving API (continuous batching over a CacheBackend)
    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> Tuple[np.ndarray, Dict]:
        """Prefill [B, S] prompts; returns (first tokens [B], cache rows).
        All rows must share one length — the scheduler groups by length
        so padding never perturbs positions (exactness over utilisation)."""
        next_tok, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(tokens, jnp.int32)})
        return np.asarray(next_tok), cache

    def _check_paged(self, block_size: int) -> None:
        check_paged_support(self.cfg)
        if self.max_len % block_size != 0:
            raise ValueError(f"engine max_len {self.max_len} must be a "
                             f"multiple of block_size {block_size}")
        if self.cfg.use_mla and getattr(self.flags, "use_paged_kernel",
                                        False):
            raise ValueError("use_paged_kernel covers GQA/MHA/MQA only; "
                             "MLA paged decode uses the latent-gather "
                             "path (drop the flag)")
        self.check_extend_support("paged")

    def _check_hybrid(self, block_size: int) -> None:
        check_hybrid_support(self.cfg)
        if self.max_len % block_size != 0:
            raise ValueError(f"engine max_len {self.max_len} must be a "
                             f"multiple of block_size {block_size}")
        if self.cfg.use_mla and getattr(self.flags, "use_paged_kernel",
                                        False):
            raise ValueError("use_paged_kernel covers GQA/MHA/MQA only; "
                             "MLA paged decode uses the latent-gather "
                             "path (drop the flag)")
        self.check_extend_support("hybrid")

    def check_extend_support(self, backend_kind: str = "slot") -> None:
        """Prefix/chunked-extend prefill has no sequence-parallel path
        yet.  On the slot/paged layouts it additionally needs a
        pure-attention decoder stack; the state/hybrid layouts instead
        *continue the sequential state scan* for recurrent layers
        (docs/STATE_CACHE.md), so only per-layer attention limits remain.
        Paged/hybrid backends always need it; slot/state backends only
        with chunked prefill enabled.  ``use_flash`` routes the suffix
        attention through the Pallas flash kernel with a static
        ``q_offset`` — chunk-invariant bitwise because k-block partition
        boundaries are fixed at ``block_k`` multiples of absolute
        position (docs/KERNELS.md)."""
        if backend_kind in STATE_KINDS:
            check_mixed_extend_support(self.cfg)
        else:
            check_paged_support(self.cfg)
        if getattr(self.flags, "model_size", 1) > 1:
            raise ValueError("extend prefill is single-host for now "
                             "(prefix-extend attention is not "
                             "sequence-parallel)")

    def check_spec_support(self, backend_kind: str = "slot") -> None:
        """Speculative decoding verifies a multi-token window through the
        decode path.  Slot/paged layouts need a pure-attention decoder
        stack (their recurrent state has no rollback); the state/hybrid
        layouts verify recurrent layers through the sequential window
        pass with state stacks + rewind (docs/STATE_CACHE.md).  Neither
        has a sliding-window mask.  Verify windows run in-kernel under
        ``use_fused_decode`` (the fused flash-decode kernel masks each
        query at ``idx <= pos + s``); the older single-query
        ``use_paged_kernel`` cannot express a window, so on its own it
        still forces the page-gather fallback and is rejected."""
        if backend_kind in STATE_KINDS:
            if self.cfg.sliding_window and "attn" in self.cfg.layer_kinds():
                raise ValueError("speculative decode has no "
                                 "sliding-window mask")
        else:
            check_paged_support(self.cfg)
        if (getattr(self.flags, "use_paged_kernel", False)
                and not getattr(self.flags, "use_fused_decode", False)):
            raise ValueError("speculative decode reads paged K/V through "
                             "the page-gather path; drop use_paged_kernel "
                             "(the single-query Pallas kernel cannot "
                             "verify a window — use use_fused_decode)")
        if getattr(self.flags, "model_size", 1) > 1:
            raise ValueError("speculative decode is single-host for now")

    def _serve_steps(self, backend) -> Dict[str, Any]:
        key = (backend.kind, getattr(backend, "block_size", 0))
        steps = self._serve.get(key)
        if steps is None:
            paged = backend.kind in ("paged", "hybrid")
            masked = backend.kind in STATE_KINDS
            if backend.kind == "hybrid":
                insert = make_hybrid_insert(self.model, backend.block_size)
            elif backend.kind == "paged":
                insert = make_paged_insert(backend.block_size)
            else:
                insert = make_slot_insert()
            layout = f"{backend.kind}/{getattr(backend, 'block_size', 0)}"
            steps = {
                "decode": self._timed(jax.jit(make_serve_decode_step(
                    self.model, self.flags, paged=paged,
                    masked_state=masked)), "serve_decode", layout),
                "insert": self._timed(jax.jit(insert), "insert", layout),
            }
            self._serve[key] = steps
        return steps

    def new_cache(self, backend):
        """Zeroed decode cache in the backend's layout: ``num_slots``
        contiguous max_len rows (slot — the state layout shares it:
        recurrent slot caches already ARE O(1) state slabs), a
        ``num_blocks`` x ``block_size`` block-pool arena with trash
        block 0 (paged), or the per-layer mix of both (hybrid)."""
        if backend.kind == "paged":
            self._check_paged(backend.block_size)
            abstract = self.model.abstract_paged_cache(backend.num_blocks,
                                                       backend.block_size)
        elif backend.kind == "hybrid":
            self._check_hybrid(backend.block_size)
            abstract = self.model.abstract_hybrid_cache(
                backend.num_slots, backend.num_blocks, backend.block_size)
        else:
            abstract = self.model.abstract_cache(backend.num_slots,
                                                 self.max_len)
        if self.mesh is None:
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                abstract)
        # mesh-sharded arena: every leaf is allocated WITH its sharding
        # (sharding/rules.py::cache_specs — kv_heads across the model
        # axis for attention K/V, the recurrent-slab axes for state
        # leaves), so per-rank HBM holds 1/tp of each block from the
        # first byte.  Jitted steps preserve these shardings (GSPMD
        # propagates them through the scatter/gather; the leak fixture
        # in tests/conftest.py asserts no silent replication drift).
        from ..sharding.rules import cache_specs
        specs = cache_specs(abstract, self.mesh)
        return jax.tree.map(
            lambda s, sh: jax.device_put(jnp.zeros(s.shape, s.dtype), sh),
            abstract, specs)

    @property
    def mesh_desc(self) -> Dict[str, Any]:
        """JSON-able mesh shape for observability tags (metrics,
        flight-recorder incidents, scheduler debug_state)."""
        from ..launch.mesh import mesh_desc
        return mesh_desc(self.mesh)

    def cache_shards(self) -> int:
        """Factor by which ONE cache block's per-rank bytes shrink under
        the serving mesh — i.e. how many times more blocks the same
        per-rank HBM holds.  GraphServer scales its default paged-arena
        size by this (capacity reflects per-rank HBM × ranks, not a
        single chip — docs/SHARDING.md).  Attention K/V shards on
        kv_heads (or head_dim when kv heads don't divide); MLA's latent
        cache on its lora rank; a stack with no attention arena (pure
        recurrent) reports 1 — its O(1) slabs are not the capacity
        bound."""
        if self.mesh is None or self.tp <= 1:
            return 1
        cfg = self.cfg
        if "attn" not in cfg.layer_kinds():
            return 1
        if getattr(cfg, "use_mla", False):
            rank = getattr(cfg, "kv_lora_rank", 0) or 0
            return self.tp if rank % self.tp == 0 else 1
        if (cfg.num_kv_heads % self.tp == 0
                or cfg.head_dim % self.tp == 0):
            return self.tp
        return 1

    def insert(self, backend, cache, rows, row: int, dst):
        """Land prefilled cache row ``row`` of ``rows`` in the cache.
        ``dst`` is the backend's write ref: a slot index (slot/state
        layouts), a [max_len // block_size] int32 page-id vector (paged
        layout, 0 = skip page), or a ``(page_ids, slot)`` pair
        (hybrid)."""
        step = self._serve_steps(backend)["insert"]
        if backend.kind == "hybrid":
            page_ids, slot = dst
            return step(cache, rows, jnp.asarray(row, jnp.int32),
                        jnp.asarray(page_ids, jnp.int32),
                        jnp.asarray(slot, jnp.int32))
        return step(cache, rows, jnp.asarray(row, jnp.int32),
                    jnp.asarray(dst, jnp.int32))

    def decode(self, backend, cache, last_tokens: np.ndarray,
               positions: np.ndarray, active: np.ndarray,
               block_tables: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, Dict]:
        """One greedy decode step across all slots.

        last_tokens/positions/active: [N] — each slot's most recent token,
        cache offset, and occupancy.  Paged backends pass their
        ``block_tables`` ([N, P] int32; inactive rows all-zero).  Returns
        ([N] next tokens, cache); inactive slots yield the pad token."""
        step = self._serve_steps(backend)["decode"]
        t0 = time.perf_counter()
        args = (self.params,
                jnp.asarray(last_tokens, jnp.int32)[:, None],
                cache,
                jnp.asarray(positions, jnp.int32),
                jnp.asarray(active, bool))
        if backend.kind in ("paged", "hybrid"):
            next_tok, cache = step(*args,
                                   jnp.asarray(block_tables, jnp.int32))
        else:
            next_tok, cache = step(*args)
        out = np.asarray(next_tok[:, 0])
        self._observe_kernel("decode", backend, t0)
        return out, cache

    def verify(self, backend, cache, tokens: np.ndarray,
               positions: np.ndarray, active: np.ndarray,
               block_tables: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, Dict]:
        """Speculative verification: score a [N, 1+k] token window per
        slot (each row: last emitted token ++ k drafted tokens, padded
        with the pad id) in one forward pass.

        Returns ([N, 1+k] greedy argmax at every window position, cache).
        Row ``b``'s window occupies cache positions
        ``positions[b]..positions[b]+k`` — the caller must guarantee
        ``positions[b] + k < max_len`` for every slot (free slots
        included: their stray writes must stay in bounds) and, on paged
        backends, must have backed every position it intends to keep
        (unbacked pages trash-route their writes).  Compiled once per
        (layout, window width)."""
        width = int(np.asarray(tokens).shape[1])
        key = (backend.kind, getattr(backend, "block_size", 0), width)
        step = self._verify_steps.get(key)
        if step is None:
            step = self._timed(jax.jit(make_verify_step(
                self.model, self.flags, paged=backend.kind == "paged")),
                "verify", self._layout(backend), str(width))
            self._verify_steps[key] = step
        t0 = time.perf_counter()
        args = (self.params, jnp.asarray(tokens, jnp.int32), cache,
                jnp.asarray(positions, jnp.int32),
                jnp.asarray(active, bool))
        if backend.kind == "paged":
            guess, cache = step(*args, jnp.asarray(block_tables, jnp.int32))
        else:
            guess, cache = step(*args)
        out = np.asarray(guess)
        self._observe_kernel("verify", backend, t0)
        return out, cache

    def verify_window(self, backend, cache, tokens: np.ndarray,
                      positions: np.ndarray, active: np.ndarray,
                      block_tables: Optional[np.ndarray] = None):
        """:meth:`verify` for the state/hybrid layouts: same window
        contract, but recurrent state slabs are left *uncommitted* and
        per-position state stacks come back alongside — the backend's
        ``truncate`` commits the accepted prefix via
        :meth:`state_rewind` (docs/STATE_CACHE.md).  Returns
        ([N, 1+k] guesses, cache, stacks)."""
        width = int(np.asarray(tokens).shape[1])
        key = (backend.kind, getattr(backend, "block_size", 0), width,
               "stacks")
        step = self._verify_steps.get(key)
        if step is None:
            step = self._timed(jax.jit(make_state_verify_step(
                self.model, self.flags, paged=backend.kind == "hybrid")),
                "verify_stacks", self._layout(backend), str(width))
            self._verify_steps[key] = step
        t0 = time.perf_counter()
        args = (self.params, jnp.asarray(tokens, jnp.int32), cache,
                jnp.asarray(positions, jnp.int32),
                jnp.asarray(active, bool))
        if backend.kind == "hybrid":
            guess, cache, stacks = step(
                *args, jnp.asarray(block_tables, jnp.int32))
        else:
            guess, cache, stacks = step(*args)
        out = np.asarray(guess)
        self._observe_kernel("verify", backend, t0)
        return out, cache, stacks

    def state_rewind(self, cache, stacks, slot: int, idx: int):
        """Commit the state after window position ``idx`` (0-based) of
        row ``slot`` from ``stacks`` (returned by :meth:`verify_window`)
        into the live state slabs; attention leaves pass through.  One
        jitted function retraces per (layout, window width)."""
        if self._state_rewind is None:
            self._state_rewind = self._timed(
                jax.jit(make_state_rewind(self.model)),
                "state_rewind", "state")
        return self._state_rewind(cache, stacks,
                                  jnp.asarray(slot, jnp.int32),
                                  jnp.asarray(idx, jnp.int32))

    def extend(self, backend, cache, suffix_tokens: np.ndarray,
               prefix_len: int, ref) -> Tuple[np.ndarray, Dict]:
        """Chunked/prefix prefill: compute ``suffix_tokens`` (positions
        ``prefix_len`` on) against the request's cached prefix and write
        the new K/V back.  ``ref`` is the backend's write ref — a slot
        index (slot/state), a ``(table_row, page_ids)`` pair (paged), or
        a ``(table_row, page_ids, slot)`` triple (hybrid).  Returns
        ([1] next token after the suffix, cache).  Compiled per
        (layout, prefix_len, suffix shape)."""
        kind = backend.kind
        key = (kind, getattr(backend, "block_size", 0), int(prefix_len))
        step = self._extend_steps.get(key)
        if step is None:
            if kind in STATE_KINDS:
                step = jax.jit(make_state_extend_step(
                    self.model, int(prefix_len), self.flags,
                    block_size=backend.block_size if kind == "hybrid"
                    else 0,
                    max_cache_len=self.max_len))
            else:
                step = jax.jit(make_extend_step(
                    self.model, int(prefix_len), self.flags,
                    block_size=backend.block_size if kind == "paged"
                    else 0,
                    max_cache_len=self.max_len))
            step = self._timed(step, "extend", self._layout(backend),
                               str(int(prefix_len)))
            self._extend_steps[key] = step
        suffix = jnp.asarray(suffix_tokens, jnp.int32)[None]
        if kind == "paged":
            table_row, page_ids = ref
            next_tok, cache = step(self.params, suffix, cache,
                                   jnp.asarray(table_row, jnp.int32),
                                   jnp.asarray(page_ids, jnp.int32))
        elif kind == "hybrid":
            table_row, page_ids, slot = ref
            next_tok, cache = step(self.params, suffix, cache,
                                   jnp.asarray(table_row, jnp.int32),
                                   jnp.asarray(page_ids, jnp.int32),
                                   jnp.asarray(slot, jnp.int32))
        else:
            next_tok, cache = step(self.params, suffix, cache,
                                   jnp.asarray(ref, jnp.int32))
        return np.asarray(next_tok), cache
