"""The serving pipeline graphs — MediaPipe's flow-limited inference pattern
(paper Fig. 3 + §6.1) applied to LLM serving.

Fixed-batch pipeline (:func:`build_serving_graph`):

    requests -> FlowLimiter -> Batcher -> LLMPrefill -> Unbatch -> responses
                     ^                                      |
                     +----------- FINISHED loopback ---------+

Continuous-batching pipeline (:func:`build_continuous_serving_graph`):

    requests -> FlowLimiter -> ContinuousBatch -+-> tokens
                     ^              ^    |      +-> responses
    control ---------|--------------+    |           |
    (cancel)         |              +-tick loop      |
                     +--------- FINISHED loopback ---+

The flow limiter bounds in-flight requests so bursts do not queue unbounded
work behind the accelerator; drops happen UPSTREAM of prefill (no wasted
work).  The heavy inference node runs on a dedicated executor (paper §3.6's
thread-locality advice).  In the continuous graph the decode loop itself is
a loopback stream: every decode step is one scheduler dispatch, so
admission, back-pressure and the tracer all see the loop at step
granularity.

Both graphs are authored with :class:`~repro.core.builder.GraphBuilder`:
ports are contract-checked as the graph is written, and the FINISHED/TICK
back edges are declared by ``b.loopback()`` handles instead of manual
``back_edge_inputs`` bookkeeping.  ``build()`` returns a plain
``GraphConfig`` for the runtime.
"""
from __future__ import annotations

from typing import Optional

from .. import calculators as _basic_calculators  # noqa: F401 (registers
#     PassThroughCalculator & co. for the loopback nodes)
from ..core.builder import GraphBuilder
from ..core.graph_config import GraphConfig


def build_serving_graph(*, batch_size: int = 4, max_in_flight: int = 2,
                        queue_size: int = 256,
                        drop_on_overload: bool = False) -> GraphConfig:
    b = GraphBuilder(num_threads=4, enable_tracer=True)
    requests = b.input("requests")
    engine_sp = b.side_input("engine")
    b.executor("inference", 1)

    finished = b.loopback()
    limiter = b.add_node(
        "FlowLimiterCalculator", name="limiter",
        inputs={"IN": requests, "FINISHED": finished},
        options={"max_in_flight": max_in_flight * batch_size,
                 "queue_size": 0 if drop_on_overload else queue_size})
    batcher = b.add_node(
        "BatcherCalculator", name="batcher",
        inputs={"REQUEST": limiter.out("OUT", name="admitted")},
        options={"batch_size": batch_size})
    engine = b.add_node(
        "LLMPrefillCalculator", name="engine",
        inputs={"BATCH": batcher.out("BATCH", name="batches")},
        side_inputs={"engine": engine_sp},
        executor="inference")
    unbatch = b.add_node(
        "UnbatchCalculator", name="unbatch",
        inputs={"BATCH_RESULT": engine.out("BATCH_RESULT",
                                           name="batch_results")})
    responses = b.output(unbatch.out("RESPONSE", name="responses"))
    loop = b.add_node("PassThroughCalculator", name="loop",
                      inputs={"responses": responses})
    finished.tie(loop.out("responses", name="responses_loop"))
    return b.build()


def build_continuous_serving_graph(*, num_slots: int = 4,
                                   max_in_flight: int = 0,
                                   queue_size: int = 1024,
                                   drop_on_overload: bool = False,
                                   max_new_tokens: int = 16,
                                   eos_id: Optional[int] = None,
                                   enable_tracer: bool = True,
                                   chunk_size: Optional[int] = None,
                                   speculate_k: int = 0,
                                   spec_ngram: int = 3,
                                   paged: bool = False,
                                   num_blocks: int = 0,
                                   block_size: int = 16,
                                   prefix_sharing: bool = True,
                                   admission: str = "preempt",
                                   watermark: int = 0,
                                   backend: Optional[str] = None,
                                   spec_window: int = 8
                                   ) -> GraphConfig:
    """Continuous-batching serving graph (the GraphServer topology).

    ``max_in_flight`` bounds requests inside the engine subsystem (waiting
    for a slot + occupying one); 0 means ``2 * num_slots`` so a full next
    wave is always staged while the current one decodes.  Beyond that the
    limiter queues up to ``queue_size`` requests — or drops immediately
    when ``drop_on_overload`` (which makes ``queue_size`` moot).

    With ``paged=True`` the engine node runs the paged KV cache
    (``num_blocks`` blocks of ``block_size`` tokens; ref-counted prefix
    sharing unless ``prefix_sharing=False``).  The GraphServer derives a
    memory-aware ``max_in_flight`` default in that mode — see
    :class:`repro.serving.server.GraphServer`.

    ``speculate_k > 0`` turns on self-speculative decoding as the
    default for every request (prompt-lookup drafting with n-grams up
    to ``spec_ngram``; see docs/SPECULATIVE.md).

    ``backend`` names the cache layout outright ("slot" | "paged" |
    "state" | "hybrid"; wins over the legacy ``paged`` flag).  "state"
    serves recurrent/mixed stacks from O(1) state slabs; "hybrid"
    (Jamba-style) pages attention K/V while recurrent layers ride state
    slabs — ``spec_window`` caps their speculative verify window
    (docs/STATE_CACHE.md).
    """
    if max_in_flight <= 0:
        max_in_flight = 2 * num_slots
    b = GraphBuilder(num_threads=4, enable_tracer=enable_tracer)
    requests = b.input("requests")
    # control bypasses the flow limiter on purpose: a cancel must reach
    # the scheduler even (especially) when the admission queue is full
    control = b.input("control")
    engine_sp = b.side_input("engine")
    b.executor("inference", 1)

    engine_opts = {"num_slots": num_slots, "max_new_tokens": max_new_tokens,
                   "eos_id": eos_id, "chunk_size": chunk_size,
                   "speculate_k": speculate_k, "spec_ngram": spec_ngram}
    if backend is not None:
        engine_opts.update({"backend": backend,
                            "spec_window": spec_window})
    if paged or backend in ("paged", "hybrid"):
        engine_opts.update({"paged": paged, "num_blocks": num_blocks,
                            "block_size": block_size,
                            "prefix_sharing": prefix_sharing,
                            "admission": admission,
                            "watermark": watermark})

    finished = b.loopback()
    tick = b.loopback()
    limiter = b.add_node(
        "FlowLimiterCalculator", name="limiter",
        inputs={"IN": requests, "FINISHED": finished},
        options={"max_in_flight": max_in_flight,
                 "queue_size": 0 if drop_on_overload else queue_size})
    engine = b.add_node(
        "ContinuousBatchCalculator", name="engine",
        inputs={"REQUEST": limiter.out("OUT", name="admitted"),
                "CONTROL": control,
                "TICK": tick},
        side_inputs={"engine": engine_sp},
        options=engine_opts,
        executor="inference")
    tokens = engine.out("TOKEN", name="tokens")
    responses = engine.out("RESPONSE", name="responses")
    ticks = engine.out("TICK_OUT", name="ticks")
    b.output(responses)
    b.output(tokens)
    tick_loop = b.add_node("PassThroughCalculator", name="tick_loop",
                           inputs={"ticks": ticks})
    tick.tie(tick_loop.out("ticks", name="tick_loop"))
    finished_loop = b.add_node("PassThroughCalculator", name="finished_loop",
                               inputs={"responses": responses})
    finished.tie(finished_loop.out("responses", name="responses_loop"))
    return b.build()
