"""The serving pipeline graph — MediaPipe's flow-limited inference pattern
(paper Fig. 3 + §6.1) applied to LLM serving:

    requests -> FlowLimiter -> Batcher -> LLMPrefill -> Unbatch -> responses
                     ^                                      |
                     +----------- FINISHED loopback ---------+

The flow limiter bounds in-flight batches so request bursts do not queue
unbounded work behind the accelerator; drops happen UPSTREAM of batching
(no wasted prefill).  The heavy inference node runs on a dedicated executor
(paper §3.6's thread-locality advice).
"""
from __future__ import annotations

from typing import Optional

from ..core.graph_config import ExecutorConfig, GraphConfig


def build_serving_graph(*, batch_size: int = 4, max_in_flight: int = 2,
                        queue_size: int = 256,
                        drop_on_overload: bool = False) -> GraphConfig:
    cfg = GraphConfig(
        input_streams=["requests"],
        output_streams=["responses"],
        input_side_packets=["engine"],
        executors=[ExecutorConfig("inference", 1)],
        num_threads=4,
        enable_tracer=True,
    )
    cfg.add_node(
        "FlowLimiterCalculator", name="limiter",
        inputs={"IN": "requests", "FINISHED": "responses_loop"},
        outputs={"OUT": "admitted"},
        options={"max_in_flight": max_in_flight * batch_size,
                 "queue_size": 0 if drop_on_overload else queue_size},
        back_edge_inputs=["FINISHED"],
    )
    cfg.add_node(
        "BatcherCalculator", name="batcher",
        inputs={"REQUEST": "admitted"},
        outputs={"BATCH": "batches"},
        options={"batch_size": batch_size},
    )
    cfg.add_node(
        "LLMPrefillCalculator", name="engine",
        inputs={"BATCH": "batches"},
        outputs={"BATCH_RESULT": "batch_results"},
        input_side_packets={"engine": "engine"},
        executor="inference",
    )
    cfg.add_node(
        "UnbatchCalculator", name="unbatch",
        inputs={"BATCH_RESULT": "batch_results"},
        outputs={"RESPONSE": "responses"},
    )
    cfg.add_node(
        "PassThroughCalculator", name="loop",
        inputs={"responses": "responses"},
        outputs={"responses": "responses_loop"},
    )
    return cfg
