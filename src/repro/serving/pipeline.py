"""The serving pipeline graphs — MediaPipe's flow-limited inference pattern
(paper Fig. 3 + §6.1) applied to LLM serving.

Fixed-batch pipeline (:func:`build_serving_graph`):

    requests -> FlowLimiter -> Batcher -> LLMPrefill -> Unbatch -> responses
                     ^                                      |
                     +----------- FINISHED loopback ---------+

Continuous-batching pipeline (:func:`build_continuous_serving_graph`):

    requests -> FlowLimiter -> ContinuousBatch -+-> tokens
                     ^              ^    |      +-> responses
                     |              +-tick loop      |
                     +--------- FINISHED loopback ---+

The flow limiter bounds in-flight requests so bursts do not queue unbounded
work behind the accelerator; drops happen UPSTREAM of prefill (no wasted
work).  The heavy inference node runs on a dedicated executor (paper §3.6's
thread-locality advice).  In the continuous graph the decode loop itself is
a loopback stream: every decode step is one scheduler dispatch, so
admission, back-pressure and the tracer all see the loop at step
granularity.
"""
from __future__ import annotations

from typing import Optional

from .. import calculators as _basic_calculators  # noqa: F401 (registers
#     PassThroughCalculator & co. for the loopback nodes)
from ..core.graph_config import ExecutorConfig, GraphConfig


def build_serving_graph(*, batch_size: int = 4, max_in_flight: int = 2,
                        queue_size: int = 256,
                        drop_on_overload: bool = False) -> GraphConfig:
    cfg = GraphConfig(
        input_streams=["requests"],
        output_streams=["responses"],
        input_side_packets=["engine"],
        executors=[ExecutorConfig("inference", 1)],
        num_threads=4,
        enable_tracer=True,
    )
    cfg.add_node(
        "FlowLimiterCalculator", name="limiter",
        inputs={"IN": "requests", "FINISHED": "responses_loop"},
        outputs={"OUT": "admitted"},
        options={"max_in_flight": max_in_flight * batch_size,
                 "queue_size": 0 if drop_on_overload else queue_size},
        back_edge_inputs=["FINISHED"],
    )
    cfg.add_node(
        "BatcherCalculator", name="batcher",
        inputs={"REQUEST": "admitted"},
        outputs={"BATCH": "batches"},
        options={"batch_size": batch_size},
    )
    cfg.add_node(
        "LLMPrefillCalculator", name="engine",
        inputs={"BATCH": "batches"},
        outputs={"BATCH_RESULT": "batch_results"},
        input_side_packets={"engine": "engine"},
        executor="inference",
    )
    cfg.add_node(
        "UnbatchCalculator", name="unbatch",
        inputs={"BATCH_RESULT": "batch_results"},
        outputs={"RESPONSE": "responses"},
    )
    cfg.add_node(
        "PassThroughCalculator", name="loop",
        inputs={"responses": "responses"},
        outputs={"responses": "responses_loop"},
    )
    return cfg


def build_continuous_serving_graph(*, num_slots: int = 4,
                                   max_in_flight: int = 0,
                                   queue_size: int = 1024,
                                   drop_on_overload: bool = False,
                                   max_new_tokens: int = 16,
                                   eos_id: Optional[int] = None,
                                   enable_tracer: bool = True
                                   ) -> GraphConfig:
    """Continuous-batching serving graph (the GraphServer topology).

    ``max_in_flight`` bounds requests inside the engine subsystem (waiting
    for a slot + occupying one); 0 means ``2 * num_slots`` so a full next
    wave is always staged while the current one decodes.  Beyond that the
    limiter queues up to ``queue_size`` requests — or drops immediately
    when ``drop_on_overload`` (which makes ``queue_size`` moot).
    """
    if max_in_flight <= 0:
        max_in_flight = 2 * num_slots
    cfg = GraphConfig(
        input_streams=["requests"],
        output_streams=["responses", "tokens"],
        input_side_packets=["engine"],
        executors=[ExecutorConfig("inference", 1)],
        num_threads=4,
        enable_tracer=enable_tracer,
    )
    cfg.add_node(
        "FlowLimiterCalculator", name="limiter",
        inputs={"IN": "requests", "FINISHED": "responses_loop"},
        outputs={"OUT": "admitted"},
        options={"max_in_flight": max_in_flight,
                 "queue_size": 0 if drop_on_overload else queue_size},
        back_edge_inputs=["FINISHED"],
    )
    engine_opts = {"num_slots": num_slots, "max_new_tokens": max_new_tokens}
    if eos_id is not None:     # omit from options: None doesn't round-trip
        engine_opts["eos_id"] = eos_id     # through the text format
    cfg.add_node(
        "ContinuousBatchCalculator", name="engine",
        inputs={"REQUEST": "admitted", "TICK": "tick_loop"},
        outputs={"TOKEN": "tokens", "RESPONSE": "responses",
                 "TICK_OUT": "ticks"},
        input_side_packets={"engine": "engine"},
        options=engine_opts,
        executor="inference",
        back_edge_inputs=["TICK"],
    )
    cfg.add_node(
        "PassThroughCalculator", name="tick_loop",
        inputs={"ticks": "ticks"},
        outputs={"ticks": "tick_loop"},
    )
    cfg.add_node(
        "PassThroughCalculator", name="finished_loop",
        inputs={"responses": "responses"},
        outputs={"responses": "responses_loop"},
    )
    return cfg
