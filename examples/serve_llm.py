#!/usr/bin/env python
"""End-to-end driver: serve a small LM through the continuous-batching
GraphServer with several concurrent client threads (requests join the
running decode batch as slots free up; tokens stream back per step).

    PYTHONPATH=src python examples/serve_llm.py

Useful knobs (all forwarded to repro.launch.serve):

* ``--backend {slot,paged,state,hybrid}`` — contiguous slot rows, the
  paged KV cache with ref-counted prefix sharing (docs/SCHEDULER.md),
  or the state-slab layouts for recurrent / Jamba-style stacks
  (docs/STATE_CACHE.md; pass a matching ``--arch``, e.g.
  ``--arch jamba_1_5_large_398b --backend hybrid``).
* ``--chunk-size N`` — chunked prefill: long prompts ingest N tokens per
  scheduler tick, interleaved with everyone else's decode steps.
* ``--speculate K`` — self-speculative decoding: draft up to K tokens
  per tick by prompt lookup, verify them in one batched pass, emit
  every accepted token at once (docs/SPECULATIVE.md).
* ``--priority N`` — cycle per-request priorities 0..N (higher priority
  is admitted first and preempted last under block pressure).
* ``--admission {preempt,reserve}`` — paged admission policy.
* ``--fixed-batch`` — the original batch-and-drain pipeline, for
  comparison.

Scheduler stats (preemptions, replayed tokens, chunked-prefill ticks,
speculative acceptance rate) are printed on exit.
"""
import sys

from repro.launch.serve import main

sys.exit(main(["--arch", "qwen3_32b", "--reduced",
               "--requests", "24", "--clients", "6",
               "--num-slots", "4", "--max-new-tokens", "8"]
              + sys.argv[1:]))
