#!/usr/bin/env python
"""End-to-end driver: serve a small LM through the continuous-batching
GraphServer with several concurrent client threads (requests join the
running decode batch as slots free up; tokens stream back per step).

    PYTHONPATH=src python examples/serve_llm.py

Pass ``--fixed-batch`` to run the original batch-and-drain pipeline
instead, for comparison, or ``--paged`` to serve over the paged KV
cache with ref-counted prefix sharing (docs/KV_CACHE.md).
"""
import sys

from repro.launch.serve import main

sys.exit(main(["--arch", "qwen3_32b", "--reduced",
               "--requests", "24", "--clients", "6",
               "--num-slots", "4", "--max-new-tokens", "8"]
              + sys.argv[1:]))
