#!/usr/bin/env python
"""End-to-end driver: serve a small LM with batched requests through the
flow-limited MediaPipe serving graph (deliverable (b): 'serve a small model
with batched requests, as the paper's kind dictates').

    PYTHONPATH=src python examples/serve_llm.py
"""
import sys

from repro.launch.serve import main

sys.exit(main(["--arch", "qwen3_32b", "--reduced",
               "--requests", "24", "--batch-size", "4",
               "--max-new-tokens", "8"]))
