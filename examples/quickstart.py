#!/usr/bin/env python
"""Quickstart: build a three-node perception pipeline, run it, inspect the
trace — the 60-second tour of the framework (paper §3).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.calculators  # noqa: F401 — registers the calculator library
from repro.core import Graph, GraphBuilder, visualizer

# 1. Declare the pipeline: frames -> detector -> annotator -> out.
#    The builder checks every port against the calculator contracts as the
#    graph is written — a typo like detect["FRMAE"] fails on that line.
b = GraphBuilder(enable_tracer=True)
frame = b.input("frame")
labels = b.side_input("labels")

detect = b.add_node("ObjectDetectorCalculator", name="detect",
                    options={"threshold": 0.4},
                    side_inputs={"labels": labels})
detect["FRAME"] = frame
detections = detect.out("DETECTIONS", name="detections")

annotate = b.add_node("AnnotationOverlayCalculator", name="annotate",
                      inputs={"FRAME": frame, "DETECTIONS": detections})
b.output(annotate.out("ANNOTATED_FRAME", name="annotated"))

cfg = b.build()      # a plain GraphConfig — runtime/text format unchanged

print(visualizer.topology_ascii(cfg))
print()

# 2. Run it over a synthetic camera feed.
g = Graph(cfg, side_packets={"labels": ["cat", "dog"]})
frames_out = []
g.observe_output_stream("annotated", lambda p: frames_out.append(p))
g.start_run()
rng = np.random.RandomState(0)
for t in range(10):
    frame = (rng.rand(64, 64) * 255).astype(np.float32)
    g.add_packet_to_input_stream("frame", frame, t)
g.close_all_input_streams()
g.wait_until_done()

# 3. The default input policy aligned every annotation with its frame.
print(f"got {len(frames_out)} annotated frames, timestamps "
      f"{[p.timestamp.value for p in frames_out]}")
assert [p.timestamp.value for p in frames_out] == list(range(10))

# 4. Inspect the trace (paper §5).
print()
print(visualizer.timeline_ascii(g.tracer, g.node_names(), width=60))
for name, h in g.tracer.node_histograms(g.node_names()).items():
    print(f"  {name:10s} runs={h['count']:3.0f} mean={h['mean_us']:.0f}us")
print("\nquickstart OK")
