#!/usr/bin/env python
"""End-to-end training driver: a reduced qwen3-family model on the
synthetic pipeline for a few hundred steps; loss must decrease.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys

from repro.launch.train import main

args = ["--arch", "qwen3_32b", "--reduced", "--host-mesh",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--lr", "1e-3", "--log-every", "20",
        "--checkpoint-dir", "/tmp/repro_ckpt"]
args += sys.argv[1:]
sys.exit(main(args))
