#!/usr/bin/env python
"""The paper's Figure-1 pipeline: real-time object detection with a slow
detection branch + fast tracking branch, merged deterministically.

  frame ──┬─> FrameSelect ─> Detector ──┐
          │                              v
          ├─> Tracker ──────────> DetectionMerge ──> AnnotationOverlay ─> out
          │        ^                     │
          │        └──── RESET loopback ─┘
          └──────────────────────────────────────────^ (frame)

The detector runs on every 4th frame; the tracker advances boxes on every
frame; the merge node's DEFAULT INPUT POLICY aligns detections with the
exact frame they came from (paper §6.1 'effectively hiding model latency').
The RESET loopback is a ``b.loopback()`` handle: consumed by the tracker
before its producer exists, tied to the merge output afterwards — the back
edge is declared automatically.

    PYTHONPATH=src python examples/object_detection.py
"""
import time

import numpy as np

import repro.calculators  # noqa: F401
from repro.core import Graph, GraphBuilder, visualizer

b = GraphBuilder(num_threads=4, enable_tracer=True)
frame = b.input("frame")
b.executor("detector_executor", 1)

select = b.add_node("FrameSelectCalculator", name="select",
                    inputs={"IN": frame}, options={"every": 4})
detect = b.add_node("ObjectDetectorCalculator", name="detect",
                    inputs={"FRAME": select.out("OUT", name="selected")},
                    options={"threshold": 0.55},
                    executor="detector_executor")  # paper §3.6 thread locality
reset = b.loopback()
track = b.add_node("TrackerCalculator", name="track",
                   inputs={"FRAME": frame, "RESET": reset})
merge = b.add_node("DetectionMergeCalculator", name="merge",
                   inputs={"DETECTIONS": detect.out("DETECTIONS",
                                                    name="detections"),
                           "TRACKED": track.out("TRACKED", name="tracked")})
merged = merge.out("MERGED", name="merged")
reset.tie(merge.out("RESET", name="reset"))
annotate = b.add_node("AnnotationOverlayCalculator", name="annotate",
                      inputs={"FRAME": frame, "DETECTIONS": merged})
b.output(annotate.out("ANNOTATED_FRAME", name="annotated"))
b.output(merged)
cfg = b.build()

print(visualizer.topology_ascii(cfg))

g = Graph(cfg)
annotated, merged_out = [], []
g.observe_output_stream("annotated", lambda p: annotated.append(p))
g.observe_output_stream("merged", lambda p: merged_out.append(
    (p.timestamp.value, len(p.payload))))
g.start_run()

rng = np.random.RandomState(1)
N = 24
base = rng.rand(64, 64).astype(np.float32) * 120
for t in range(N):
    # a bright moving square = the "object"
    frame = base.copy()
    x = 8 + 2 * t
    frame[20:36, x:x + 16] += 120
    g.add_packet_to_input_stream("frame", frame, t)
    time.sleep(0.002)
g.close_all_input_streams()
g.wait_until_done()

# every frame got an annotated output, perfectly aligned
stamps = [p.timestamp.value for p in annotated]
assert stamps == list(range(N)), stamps
det_counts = dict(merged_out)
print(f"\n{N} frames annotated; detections per frame: "
      f"{[det_counts.get(t, 0) for t in range(N)]}")
assert any(c > 0 for c in det_counts.values()), "object never detected"

print()
print(visualizer.timeline_ascii(g.tracer, g.node_names(), width=64))
print("\nobject_detection OK")
