#!/usr/bin/env python
"""The paper's Figure-5 pipeline: face-landmark detection + portrait
segmentation on DISJOINT frame subsets (demux), temporally interpolated
back onto every frame, overlaid in sync (paper §6.2).

  frame ─> Demux ─┬─ OUT0 ─> FaceLandmark ──> Interp(landmarks) ─┐
                  └─ OUT1 ─> Segmentation ──> Interp(mask) ──────┤
  frame ─────────────────────────────────────────────────────────┴─> Overlay

    PYTHONPATH=src python examples/face_landmark.py
"""
import numpy as np

import repro.calculators  # noqa: F401
from repro.core import Graph, GraphBuilder, visualizer

b = GraphBuilder(num_threads=4, enable_tracer=True)
frame = b.input("frame")


def interpolated(name, value, tick, out_name):
    """A 'subgraph' in the builder API is just a Python function taking and
    returning stream handles (paper §3.6 composition, no expansion pass)."""
    node = b.add_node("TemporalInterpolationCalculator", name=name,
                      inputs={"VALUE": value, "TICK": tick})
    return node.out("OUT", name=out_name)


demux = b.add_node("DemuxCalculator", name="demux", inputs={"IN": frame})
frames_lm = demux.out("OUT0", name="frames_lm")
frames_seg = demux.out("OUT1", name="frames_seg")

landmarks = b.add_node("FaceLandmarkCalculator", name="landmarks",
                       inputs={"FRAME": frames_lm},
                       options={"num_landmarks": 5})
segment = b.add_node("SegmentationCalculator", name="segment",
                     inputs={"FRAME": frames_seg})

lm_dense = interpolated("lm_interp",
                        landmarks.out("LANDMARKS", name="lm_sparse"),
                        frame, "lm_dense")
mask_dense = interpolated("mask_interp",
                          segment.out("MASK", name="mask_sparse"),
                          frame, "mask_dense")

overlay = b.add_node("AnnotationOverlayCalculator", name="overlay",
                     inputs={"FRAME": frame, "LANDMARKS": lm_dense,
                             "MASK": mask_dense})
b.output(overlay.out("ANNOTATED_FRAME", name="ANNOTATED_FRAME"))
cfg = b.build()

print(visualizer.topology_ascii(cfg))

g = Graph(cfg)
out = []
g.observe_output_stream("ANNOTATED_FRAME", lambda p: out.append(p))
g.start_run()
rng = np.random.RandomState(2)
N = 16
for t in range(N):
    frame = (rng.rand(48, 48) * 200).astype(np.float32)
    frame[12:36, 16:32] += 55.0      # the "face"
    g.add_packet_to_input_stream("frame", frame, t)
g.close_all_input_streams()
g.wait_until_done()

stamps = [p.timestamp.value for p in out]
print(f"\n{len(out)} frames annotated, timestamps {stamps}")
assert stamps == list(range(N))
assert out[0].payload.shape == (48, 48)

print()
print(visualizer.timeline_ascii(g.tracer, g.node_names(), width=64))
print("\nface_landmark OK")
