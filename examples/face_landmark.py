#!/usr/bin/env python
"""The paper's Figure-5 pipeline: face-landmark detection + portrait
segmentation on DISJOINT frame subsets (demux), temporally interpolated
back onto every frame, overlaid in sync (paper §6.2).

  frame ─> Demux ─┬─ OUT0 ─> FaceLandmark ──> Interp(landmarks) ─┐
                  └─ OUT1 ─> Segmentation ──> Interp(mask) ──────┤
  frame ─────────────────────────────────────────────────────────┴─> Overlay

    PYTHONPATH=src python examples/face_landmark.py
"""
import numpy as np

import repro.calculators  # noqa: F401
from repro.core import Graph, GraphConfig, visualizer

cfg = GraphConfig(
    input_streams=["frame"],
    output_streams=["ANNOTATED_FRAME"],
    num_threads=4,
    enable_tracer=True,
)
cfg.add_node("DemuxCalculator", name="demux",
             inputs={"IN": "frame"},
             outputs={"OUT0": "frames_lm", "OUT1": "frames_seg"})
cfg.add_node("FaceLandmarkCalculator", name="landmarks",
             inputs={"FRAME": "frames_lm"},
             outputs={"LANDMARKS": "lm_sparse"},
             options={"num_landmarks": 5})
cfg.add_node("SegmentationCalculator", name="segment",
             inputs={"FRAME": "frames_seg"},
             outputs={"MASK": "mask_sparse"})
cfg.add_node("TemporalInterpolationCalculator", name="lm_interp",
             inputs={"VALUE": "lm_sparse", "TICK": "frame"},
             outputs={"OUT": "lm_dense"})
cfg.add_node("TemporalInterpolationCalculator", name="mask_interp",
             inputs={"VALUE": "mask_sparse", "TICK": "frame"},
             outputs={"OUT": "mask_dense"})
cfg.add_node("AnnotationOverlayCalculator", name="overlay",
             inputs={"FRAME": "frame", "LANDMARKS": "lm_dense",
                     "MASK": "mask_dense"},
             outputs={"ANNOTATED_FRAME": "ANNOTATED_FRAME"})

print(visualizer.topology_ascii(cfg))

g = Graph(cfg)
out = []
g.observe_output_stream("ANNOTATED_FRAME", lambda p: out.append(p))
g.start_run()
rng = np.random.RandomState(2)
N = 16
for t in range(N):
    frame = (rng.rand(48, 48) * 200).astype(np.float32)
    frame[12:36, 16:32] += 55.0      # the "face"
    g.add_packet_to_input_stream("frame", frame, t)
g.close_all_input_streams()
g.wait_until_done()

stamps = [p.timestamp.value for p in out]
print(f"\n{len(out)} frames annotated, timestamps {stamps}")
assert stamps == list(range(N))
assert out[0].payload.shape == (48, 48)

print()
print(visualizer.timeline_ascii(g.tracer, g.node_names(), width=64))
print("\nface_landmark OK")
